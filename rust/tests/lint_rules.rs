//! Fixture battery for the `ringada-lint` static-analysis pass: every rule
//! has must-fire and must-pass snippets, the `cfg(test)` exemption and
//! `lint: allow` annotations are exercised end-to-end, ratchet
//! increase/decrease behavior is pinned, and — the gate itself — the
//! crate's own `src/` tree must scan clean against the committed
//! `lint_ratchet.json`.
//!
//! Fixtures live in string literals here in `tests/`, which the lint pass
//! never scans (its root is `src/`), so nothing in this file can trip the
//! real gate.

use std::collections::BTreeMap;
use std::path::Path;

use ringada::lint::ratchet::Ratchet;
use ringada::lint::rules::Rule;
use ringada::lint::{run, scan_source};

/// Shorthand: (line, rule) pairs of all findings in a fixture.
fn findings(src: &str) -> Vec<(usize, Rule)> {
    scan_source("fixture.rs", src).findings.iter().map(|f| (f.line, f.rule)).collect()
}

fn unwraps(src: &str) -> Vec<usize> {
    scan_source("fixture.rs", src).unwrap_lines
}

// ------------------------------------------------------------ R1

#[test]
fn hash_collections_must_fire() {
    assert_eq!(
        findings("use std::collections::HashMap;\n"),
        vec![(1, Rule::HashCollections)]
    );
    assert_eq!(
        findings("fn f() -> HashSet<u32> { todo!() }\n"),
        vec![(1, Rule::HashCollections)]
    );
}

#[test]
fn hash_collections_must_pass() {
    assert!(findings("use std::collections::{BTreeMap, BTreeSet};\n").is_empty());
    // Identifier containing the pattern is not the pattern.
    assert!(findings("struct MyHashMapLike;\n").is_empty());
    // Comments and strings never fire.
    assert!(findings("// a HashMap would be wrong here\nlet s = \"HashMap\";\n").is_empty());
}

// ------------------------------------------------------------ R2

#[test]
fn partial_cmp_must_fire() {
    assert_eq!(
        findings("xs.sort_by(|a, b| a.partial_cmp(b).unwrap());\n"),
        vec![(1, Rule::PartialCmp)],
        "the sort itself is whole-element, so only R2 fires"
    );
}

#[test]
fn partial_cmp_must_pass() {
    // The legitimate appearance: a PartialOrd impl delegating to Ord.
    let ok = "\
impl PartialOrd for Key {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}
";
    assert!(findings(ok).is_empty());
    assert!(findings("xs.sort_by(|a, b| a.total_cmp(b));\n").is_empty());
}

// ------------------------------------------------------------ R3

#[test]
fn ambient_entropy_must_fire() {
    for src in [
        "let t = Instant::now();\n",
        "let t = std::time::SystemTime::now();\n",
        "let h: RandomState = Default::default();\n",
        "let r = thread_rng();\n",
    ] {
        assert_eq!(
            findings(src),
            vec![(1, Rule::AmbientEntropy)],
            "fixture {src:?}"
        );
    }
}

#[test]
fn ambient_entropy_must_pass() {
    assert!(findings("let d = Duration::from_secs_f64(1.5);\n").is_empty());
    assert!(findings("let r = Rng::new(seed);\n").is_empty());
}

// ------------------------------------------------------------ R5

#[test]
fn sort_tie_break_must_fire() {
    // Tuple projection, field projection, index projection — with no
    // `.then` chain, all three leave equal keys input-order dependent.
    assert_eq!(
        findings("v.sort_by(|a, b| a.0.total_cmp(&b.0));\n"),
        vec![(1, Rule::SortTieBreak)]
    );
    assert_eq!(
        findings("v.sort_unstable_by(|a, b| a.score.total_cmp(&b.score));\n"),
        vec![(1, Rule::SortTieBreak)]
    );
    // Multi-line closure anchors the finding at the call site.
    let f = findings("let m = xs\n    .max_by(|&a, &b| {\n        rate[cur][a].total_cmp(&rate[cur][b])\n    });\n");
    assert_eq!(f, vec![(2, Rule::SortTieBreak)]);
}

#[test]
fn sort_tie_break_must_pass() {
    assert!(findings("v.sort_by(|a, b| a.0.total_cmp(&b.0).then(a.1.cmp(&b.1)));\n").is_empty());
    assert!(
        findings("v.max_by(|a, b| a.s.total_cmp(&b.s).then_with(|| a.id.cmp(&b.id)));\n")
            .is_empty()
    );
    // Whole-element comparisons are total by construction.
    assert!(findings("xs.sort_by(|a, b| a.total_cmp(b));\n").is_empty());
    assert!(findings("xs.sort_unstable_by(f64::total_cmp);\n").is_empty());
    // Key-projection sorts through Ord are not float sorts at all.
    assert!(findings("v.sort_by_key(|a| a.id);\n").is_empty());
    assert!(findings("v.sort_by(|a, b| a.id.cmp(&b.id));\n").is_empty());
}

// ------------------------------------------------------------ R6

#[test]
fn parallel_primitives_must_fire() {
    assert_eq!(
        findings("let h = std::thread::spawn(move || work());\n"),
        vec![(1, Rule::ParallelPrimitives)]
    );
    assert_eq!(
        findings("use std::sync::mpsc::channel;\n"),
        vec![(1, Rule::ParallelPrimitives)]
    );
    // A Mutex-accumulated result merges in lock-acquisition order.
    assert_eq!(
        findings("let acc = std::sync::Mutex::new(Vec::new());\n"),
        vec![(1, Rule::ParallelPrimitives)]
    );
}

#[test]
fn parallel_primitives_must_fire_on_pipeline_antipatterns() {
    // The shapes the planning pipeline must NOT take: a Mutex-guarded
    // shared plan cache (merge order = lock order) and a channel draining
    // worker results (arrival order = scheduler order) both fire.
    assert_eq!(
        findings("let cache = std::sync::Mutex::new(PlanCache::default());\n"),
        vec![(1, Rule::ParallelPrimitives)]
    );
    assert_eq!(
        findings("let (tx, rx) = mpsc::channel(); workers.send(tx);\n"),
        vec![(1, Rule::ParallelPrimitives)]
    );
}

#[test]
fn parallel_primitives_pass_the_pipeline_fan_out_idiom() {
    // The planning pipeline's actual shape: `exec::par_map` over a
    // deduped request batch against Arc-shared read-only state, results
    // committed in batch order.  No raw primitive appears, nothing fires.
    let fan_out = "\
let staged = crate::exec::par_map(threads, &batch, |_, (_, req)| {
    stage_plan(&Planner::new(&req.meta, search_pool, req.costs), &req.devices)
});
for (key, plan) in batch.into_iter().map(|(k, _)| k).zip(staged) {
    pipeline.staged.insert(key, plan);
}
";
    assert!(findings(fan_out).is_empty());
    assert!(findings("let pool = std::sync::Arc::new(cfg.pool.clone());\n").is_empty());
    assert!(findings("let shared = Arc::clone(pool);\n").is_empty());
}

#[test]
fn parallel_primitives_must_pass() {
    // The fork-join core's own idiom: scoped spawns, not thread::spawn.
    assert!(findings("std::thread::scope(|scope| { scope.spawn(|| f()); });\n").is_empty());
    // The exec core itself is exempt wholesale — same source, exec path.
    let src = "let h = std::thread::spawn(f);\nlet acc = Mutex::new(0);\n";
    assert_eq!(findings(src).len(), 2);
    assert!(scan_source("src/exec/mod.rs", src).findings.is_empty());
    // The escape hatch with a reason waives line by line.
    let waived =
        "let acc = std::sync::Mutex::new(0); // lint: allow(parallel-primitives, side table)\n";
    assert!(findings(waived).is_empty());
}

// ------------------------------------------------------ cfg(test) spans

#[test]
fn cfg_test_items_are_exempt_from_every_rule() {
    let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    use std::collections::HashMap;
    fn t() {
        let i = Instant::now();
        xs.sort_by(|a, b| a.0.partial_cmp(&b.0).unwrap());
        v.unwrap();
    }
}
";
    let scan = scan_source("fixture.rs", src);
    assert!(scan.findings.is_empty(), "{:?}", scan.findings);
    assert!(scan.unwrap_lines.is_empty());
}

#[test]
fn test_attribute_fn_is_exempt_but_surrounding_code_is_not() {
    let src = "\
use std::collections::HashMap;
#[test]
fn check() {
    let m = HashMap::new();
}
";
    assert_eq!(findings(src), vec![(1, Rule::HashCollections)]);
}

// ------------------------------------------------------ allow annotations

#[test]
fn allow_waives_the_named_rule_on_the_annotated_line() {
    let src =
        "let t = Instant::now(); // lint: allow(ambient-entropy, fixture proves the waiver)\n";
    assert!(findings(src).is_empty());
}

#[test]
fn allow_on_its_own_line_covers_the_next_code_line_only() {
    let src = "\
// lint: allow(hash-collections, first import is justified)
use std::collections::HashMap;
use std::collections::HashSet;
";
    assert_eq!(findings(src), vec![(3, Rule::HashCollections)]);
}

#[test]
fn allow_for_a_different_rule_does_not_waive() {
    let src = "let t = Instant::now(); // lint: allow(hash-collections, wrong rule)\n";
    assert_eq!(findings(src), vec![(1, Rule::AmbientEntropy)]);
}

#[test]
fn malformed_allow_is_a_gating_finding() {
    for src in [
        "x(); // lint: allow(not-a-rule, reason)\n",
        "x(); // lint: allow(ambient-entropy)\n",
        "x(); // lint: allow(ambient-entropy,   )\n",
        "x(); // lint: allow(bad-allow, the waiver rule itself is not waivable)\n",
    ] {
        assert_eq!(findings(src), vec![(1, Rule::BadAllow)], "fixture {src:?}");
    }
}

// ------------------------------------------------------------ ratchet

fn counts_of(entries: &[(&str, &[usize])]) -> BTreeMap<String, Vec<usize>> {
    entries.iter().map(|(f, l)| (f.to_string(), l.to_vec())).collect()
}

#[test]
fn unwrap_and_expect_are_counted_per_line() {
    let src = "\
fn f() {
    a.unwrap();
    b.expect(\"because\").unwrap();
}
";
    assert_eq!(unwraps(src), vec![2, 3, 3]);
    // unwrap_or / unwrap_or_else / unwrap_or_default are error handling,
    // not panic paths.
    assert!(unwraps("let x = o.unwrap_or(1) + p.unwrap_or_else(f) + q.unwrap_or_default();\n")
        .is_empty());
}

#[test]
fn ratchet_increase_fires_at_the_first_over_budget_call() {
    let budget = Ratchet::from_counts(
        &[("src/a.rs".to_string(), 2usize)].into_iter().collect(),
    );
    let live = counts_of(&[("src/a.rs", &[10, 20, 30])]);
    let f = budget.compare(&live);
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].line, f[0].rule), (30, Rule::UnwrapRatchet));
}

#[test]
fn ratchet_decrease_and_deleted_files_are_stale_findings() {
    let budget = Ratchet::from_counts(
        &[("src/a.rs".to_string(), 3usize), ("src/gone.rs".to_string(), 1)]
            .into_iter()
            .collect(),
    );
    let f = budget.compare(&counts_of(&[("src/a.rs", &[10])]));
    assert_eq!(f.len(), 2);
    assert!(f.iter().all(|f| f.rule == Rule::UnwrapRatchet));
    assert!(f.iter().all(|f| f.message.contains("stale")));
}

#[test]
fn ratchet_equal_counts_pass_and_new_files_have_zero_budget() {
    let budget = Ratchet::from_counts(
        &[("src/a.rs".to_string(), 1usize)].into_iter().collect(),
    );
    assert!(budget.compare(&counts_of(&[("src/a.rs", &[5])])).is_empty());
    let f = budget.compare(&counts_of(&[("src/a.rs", &[5]), ("src/new.rs", &[9])]));
    assert_eq!(f.len(), 1);
    assert_eq!((f[0].file.as_str(), f[0].line), ("src/new.rs", 9));
}

// --------------------------------------------------- the gate itself

#[test]
fn the_tree_is_lint_clean_against_the_committed_ratchet() {
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let (all, scan) = run(
        &manifest.join("src"),
        &manifest.join("lint_ratchet.json"),
        false,
    )
    .expect("lint scan over src/");
    let rendered: Vec<String> = all.iter().map(|f| f.render()).collect();
    assert!(all.is_empty(), "lint findings in the tree:\n{}", rendered.join("\n"));
    assert!(scan.files_scanned >= 40, "src/ walk found only {} files", scan.files_scanned);
}

#[test]
fn the_committed_ratchet_is_byte_stable_under_update() {
    // `--update-ratchet` must be idempotent on a clean tree: parsing the
    // committed file and re-serializing reproduces it byte for byte.
    let manifest = Path::new(env!("CARGO_MANIFEST_DIR"));
    let path = manifest.join("lint_ratchet.json");
    let committed = std::fs::read_to_string(&path).expect("committed lint_ratchet.json");
    let parsed = Ratchet::parse(&committed).expect("parse committed ratchet");
    assert_eq!(format!("{}\n", parsed.to_json_string()), committed);
}
