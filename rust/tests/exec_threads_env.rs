//! `RINGADA_THREADS` precedence tests for `exec::resolve_threads`.
//!
//! These live in their own integration-test binary on purpose: they
//! mutate the process environment, and every test in this file holds one
//! shared lock while doing so.  Keeping them out of
//! `tests/parallel_parity.rs` means no planner/fleet parity test can
//! observe a half-mutated environment, and the original value is always
//! restored (CI runs the suite under a `RINGADA_THREADS` matrix).

use std::sync::Mutex;

use ringada::exec::{resolve_threads, THREADS_ENV};

static ENV_LOCK: Mutex<()> = Mutex::new(());

/// Run `f` with `THREADS_ENV` set to `value` (or unset for `None`),
/// restoring the prior value afterwards — even on panic the poisoned
/// lock fails the remaining tests loudly rather than leaking state.
fn with_env<R>(value: Option<&str>, f: impl FnOnce() -> R) -> R {
    let _guard = ENV_LOCK.lock().unwrap();
    let saved = std::env::var_os(THREADS_ENV);
    match value {
        Some(v) => std::env::set_var(THREADS_ENV, v),
        None => std::env::remove_var(THREADS_ENV),
    }
    let out = f();
    match saved {
        Some(v) => std::env::set_var(THREADS_ENV, v),
        None => std::env::remove_var(THREADS_ENV),
    }
    out
}

#[test]
fn unset_env_uses_the_requested_count() {
    with_env(None, || {
        assert_eq!(resolve_threads(1).unwrap(), 1);
        assert_eq!(resolve_threads(3).unwrap(), 3);
        assert!(resolve_threads(0).is_err(), "zero workers is a config error");
    });
}

#[test]
fn valid_env_overrides_any_requested_count() {
    with_env(Some("8"), || {
        assert_eq!(resolve_threads(1).unwrap(), 8, "env must beat the config key");
        assert_eq!(resolve_threads(3).unwrap(), 8);
    });
    with_env(Some(" 6 "), || {
        assert_eq!(resolve_threads(2).unwrap(), 6, "surrounding whitespace is tolerated");
    });
    with_env(Some("1"), || {
        assert_eq!(resolve_threads(4).unwrap(), 1, "env can force the sequential path");
    });
}

#[test]
fn invalid_env_fails_loudly_instead_of_silently_sequential() {
    for bad in ["0", "lots", "", "-2", "1.5"] {
        with_env(Some(bad), || {
            let err = resolve_threads(3).unwrap_err().to_string();
            assert!(
                err.contains(THREADS_ENV),
                "RINGADA_THREADS={bad:?}: error must name the variable, got: {err}"
            );
        });
    }
}
