//! Layer-assignment planner (paper §IV.1: the coordinator "determines the
//! layer assignment policy based on the collected system status
//! information").  The paper leaves the algorithm unspecified; DESIGN.md §5
//! documents ours:
//!
//! * objective — minimize the pipeline bottleneck
//!   `max_s work(s)/speed(dev_s) + transfer(s → s+1)`
//!   over contiguous partitions and ring orderings;
//! * method — exact contiguous-partition DP for a fixed device order
//!   (O(U·L²)), wrapped in exhaustive order search for U ≤ 8 and a
//!   speed-descending greedy order beyond;
//! * constraint — per-device memory budgets `C_u^mem` (checked with the
//!   RingAda full-depth memory model, the worst case).

use crate::config::ClusterConfig;
use crate::coordinator::ring::LayerAssignment;
use crate::error::{Error, Result};
use crate::model::{MemoryModel, ModelMeta};
use crate::config::Scheme;

/// Planner inputs that come from profiling (the LUT) rather than configs.
#[derive(Debug, Clone, Copy)]
pub struct PlannerCosts {
    /// Seconds for one block forward on a speed-1.0 device.
    pub block_fwd_s: f64,
    /// Bytes of one inter-stage activation transfer.
    pub activation_bytes: usize,
}

#[derive(Debug, Clone)]
pub struct Plan {
    pub assignment: LayerAssignment,
    /// Predicted bottleneck stage time (seconds/batch) — the planner's
    /// objective value.
    pub bottleneck_s: f64,
}

/// Exact DP over contiguous partitions for a fixed device order: minimize
/// the max stage cost.  `stage_cost(dev, blocks)` must be monotone in
/// `blocks`.
fn partition_dp(
    order: &[usize],
    layers: usize,
    stage_cost: &dyn Fn(usize, usize) -> f64,
) -> (Vec<usize>, f64) {
    let u = order.len();
    // dp[s][l] = minimal bottleneck placing the first l blocks on the first
    // s ring positions, every position non-empty.
    let inf = f64::INFINITY;
    let mut dp = vec![vec![inf; layers + 1]; u + 1];
    let mut choice = vec![vec![0usize; layers + 1]; u + 1];
    dp[0][0] = 0.0;
    for s in 1..=u {
        for l in s..=layers - (u - s) {
            for prev in (s - 1)..l {
                let cost = stage_cost(order[s - 1], l - prev);
                let cand = dp[s - 1][prev].max(cost);
                if cand < dp[s][l] {
                    dp[s][l] = cand;
                    choice[s][l] = prev;
                }
            }
        }
    }
    // Recover block counts.
    let mut counts = vec![0usize; u];
    let mut l = layers;
    for s in (1..=u).rev() {
        let prev = choice[s][l];
        counts[s - 1] = l - prev;
        l = prev;
    }
    (counts, dp[u][layers])
}

/// The planner proper.
pub struct Planner<'a> {
    pub meta: &'a ModelMeta,
    pub cluster: &'a ClusterConfig,
    pub costs: PlannerCosts,
}

impl<'a> Planner<'a> {
    pub fn new(meta: &'a ModelMeta, cluster: &'a ClusterConfig, costs: PlannerCosts) -> Self {
        Planner { meta, cluster, costs }
    }

    fn stage_cost(&self, dev: usize, blocks: usize, next_dev: usize) -> f64 {
        let compute = self.costs.block_fwd_s * blocks as f64
            / self.cluster.devices[dev].compute_speed;
        let rate = self.cluster.rate_bytes_per_s[dev][next_dev];
        let transfer = self.costs.activation_bytes as f64 / rate + self.cluster.link_latency_s;
        compute + transfer
    }

    fn plan_for_order(&self, order: &[usize]) -> Option<Plan> {
        let layers = self.meta.hyper.layers;
        let u = order.len();
        if layers < u {
            return None;
        }
        // Transfer cost depends on the *next* device in ring order; bind it
        // via position lookup inside the DP cost closure.
        let cost = |dev: usize, blocks: usize| {
            let pos = order.iter().position(|&d| d == dev).unwrap();
            let next = order[(pos + 1) % u];
            self.stage_cost(dev, blocks, next)
        };
        let (counts, bottleneck) = partition_dp(order, layers, &cost);
        if !bottleneck.is_finite() {
            return None;
        }
        // `order` may be a survivor subset of the cluster (re-planning
        // after a dropout), so validate against the full device count.
        let assignment =
            LayerAssignment::from_counts_for_devices(order.to_vec(), &counts, self.cluster.len())
                .ok()?;
        // Memory feasibility: worst case is full unfreeze depth.
        let mm = MemoryModel::new(self.meta.clone());
        let unfrozen = assignment.counts();
        let (per, _) = mm.cluster_peak(Scheme::RingAda, &counts, &unfrozen, 1);
        for (pos, b) in per.iter().enumerate() {
            let dev = assignment.order[pos];
            if b.total() > self.cluster.devices[dev].mem_bytes {
                return None;
            }
        }
        Some(Plan { assignment, bottleneck_s: bottleneck })
    }

    /// Search ring orders: exhaustive for U ≤ 8, speed-descending greedy
    /// otherwise.  Returns the best feasible plan.
    pub fn plan(&self) -> Result<Plan> {
        let all: Vec<usize> = (0..self.cluster.len()).collect();
        self.plan_for_devices(&all)
    }

    /// Plan over a subset of the cluster's devices — the re-planning path
    /// after a dropout.  `devices` keep their original cluster indices (the
    /// simulator's resource clocks and the rate matrix stay valid); the
    /// resulting ring simply has fewer positions.
    pub fn plan_for_devices(&self, devices: &[usize]) -> Result<Plan> {
        let n = devices.len();
        if n == 0 {
            return Err(Error::Plan("no surviving devices to plan over".into()));
        }
        for &d in devices {
            if d >= self.cluster.len() {
                return Err(Error::Plan(format!(
                    "device {d} out of range (cluster has {})",
                    self.cluster.len()
                )));
            }
        }
        let mut best: Option<Plan> = None;
        let mut consider = |plan: Option<Plan>| {
            if let Some(p) = plan {
                if best.as_ref().map_or(true, |b| p.bottleneck_s < b.bottleneck_s) {
                    best = Some(p);
                }
            }
        };
        if n <= 8 {
            let mut order: Vec<usize> = devices.to_vec();
            permute(&mut order, 0, &mut |perm| consider(self.plan_for_order(perm)));
        } else {
            let mut order: Vec<usize> = devices.to_vec();
            order.sort_by(|&a, &b| {
                self.cluster.devices[b]
                    .compute_speed
                    .partial_cmp(&self.cluster.devices[a].compute_speed)
                    .unwrap()
            });
            consider(self.plan_for_order(&order));
            consider(self.plan_for_order(&devices.to_vec()));
        }
        best.ok_or_else(|| {
            Error::Plan("no feasible layer assignment (memory budgets too small?)".into())
        })
    }

    /// Baseline for the ablation bench: uniform split in id order.
    pub fn uniform_plan(&self) -> Result<Plan> {
        let layers = self.meta.hyper.layers;
        let n = self.cluster.len();
        let assignment = LayerAssignment::uniform(n, layers);
        let mut bottleneck: f64 = 0.0;
        for (pos, &(s, e)) in assignment.blocks.iter().enumerate() {
            let dev = assignment.order[pos];
            let next = assignment.order[(pos + 1) % n];
            bottleneck = bottleneck.max(self.stage_cost(dev, e - s, next));
        }
        Ok(Plan { assignment, bottleneck_s: bottleneck })
    }
}

fn permute(xs: &mut Vec<usize>, k: usize, f: &mut impl FnMut(&[usize])) {
    if k == xs.len() {
        f(xs);
        return;
    }
    for i in k..xs.len() {
        xs.swap(k, i);
        permute(xs, k + 1, f);
        xs.swap(k, i);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::ModelHyper;

    fn meta(layers: usize) -> ModelMeta {
        ModelMeta {
            hyper: ModelHyper {
                name: "t".into(),
                vocab: 512,
                hidden: 64,
                layers,
                heads: 4,
                ffn: 256,
                bottleneck: 16,
                seq: 32,
                batch: 4,
                init_std: 0.02,
            },
            embed_params: 512 * 64,
            block_backbone_params: 100_000,
            block_adapter_params: 2_128,
            head_params: 130,
        }
    }

    fn costs() -> PlannerCosts {
        PlannerCosts { block_fwd_s: 0.010, activation_bytes: 4 * 32 * 64 * 4 }
    }

    #[test]
    fn homogeneous_cluster_gets_even_split() {
        let m = meta(12);
        let cl = ClusterConfig::homogeneous(4, 1e9);
        let plan = Planner::new(&m, &cl, costs()).plan().unwrap();
        assert_eq!(plan.assignment.counts(), vec![3, 3, 3, 3]);
    }

    #[test]
    fn faster_devices_get_more_blocks() {
        let m = meta(12);
        let mut cl = ClusterConfig::homogeneous(4, 1e9);
        cl.devices[2].compute_speed = 3.0; // one much faster device
        let plan = Planner::new(&m, &cl, costs()).plan().unwrap();
        let pos = plan.assignment.position_of_device(2).unwrap();
        let counts = plan.assignment.counts();
        assert!(
            counts[pos] > 3,
            "fast device got {} blocks in {counts:?}",
            counts[pos]
        );
        // And the plan beats the uniform baseline.
        let uni = Planner::new(&m, &cl, costs()).uniform_plan().unwrap();
        assert!(plan.bottleneck_s <= uni.bottleneck_s + 1e-12);
    }

    #[test]
    fn memory_budget_excludes_overloaded_devices() {
        let m = meta(8);
        let mut cl = ClusterConfig::homogeneous(2, 1e9);
        // Device 1 can hold almost nothing.
        cl.devices[1].mem_bytes = 1 << 20;
        let plan = Planner::new(&m, &cl, costs()).plan();
        // Either infeasible (both small) or device 1 gets the minimum.
        if let Ok(p) = plan {
            let pos = p.assignment.position_of_device(1).unwrap();
            assert_eq!(p.assignment.counts()[pos], 1);
        }
    }

    #[test]
    fn plan_covers_all_blocks_and_validates() {
        let m = meta(14);
        let cl = ClusterConfig::paper_default();
        let plan = Planner::new(&m, &cl, costs()).plan().unwrap();
        plan.assignment.validate(14).unwrap();
        assert!(plan.bottleneck_s > 0.0);
    }

    #[test]
    fn subset_plan_covers_all_blocks_on_survivors() {
        // Device 2 dropped out of the paper's 4-device cluster: the plan
        // must cover all 14 blocks using only {0, 1, 3}, keeping original
        // device ids.
        let m = meta(14);
        let cl = ClusterConfig::paper_default();
        let plan = Planner::new(&m, &cl, costs()).plan_for_devices(&[0, 1, 3]).unwrap();
        plan.assignment.validate_for_devices(14, 4).unwrap();
        assert_eq!(plan.assignment.num_positions(), 3);
        assert!(!plan.assignment.order.contains(&2));
        assert_eq!(plan.assignment.counts().iter().sum::<usize>(), 14);
        // A smaller ring can't beat the full one on bottleneck time.
        let full = Planner::new(&m, &cl, costs()).plan().unwrap();
        assert!(plan.bottleneck_s >= full.bottleneck_s - 1e-12);
    }

    #[test]
    fn subset_plan_rejects_bad_device_ids() {
        let m = meta(8);
        let cl = ClusterConfig::homogeneous(3, 1e9);
        let p = Planner::new(&m, &cl, costs());
        assert!(p.plan_for_devices(&[]).is_err());
        assert!(p.plan_for_devices(&[0, 3]).is_err());
    }

    #[test]
    fn infeasible_when_fewer_blocks_than_devices() {
        let m = meta(2);
        let cl = ClusterConfig::homogeneous(4, 1e9);
        assert!(Planner::new(&m, &cl, costs()).plan().is_err());
    }

    #[test]
    fn dp_is_optimal_on_small_instance() {
        // 2 devices, speeds 1 and 2, 6 blocks, negligible comms: optimal
        // split puts 2 blocks on the slow device, 4 on the fast one
        // (bottleneck 2.0 block-times) — any other split is worse.
        let m = meta(6);
        let mut cl = ClusterConfig::homogeneous(2, 1e12);
        cl.link_latency_s = 0.0;
        cl.devices[1].compute_speed = 2.0;
        let plan = Planner::new(&m, &cl, costs()).plan().unwrap();
        let pos0 = plan.assignment.position_of_device(0).unwrap();
        let counts = plan.assignment.counts();
        assert_eq!(counts[pos0], 2, "slow device should get 2 of 6: {counts:?}");
    }
}
