//! Typed configuration for experiments: cluster, training, and scheme.
//!
//! Everything round-trips through JSON (via the in-crate [`Json`] module)
//! so experiments are reproducible from files (`ringada train --config
//! exp.json`), and builders provide the programmatic path used by the
//! examples and benches.

use std::path::PathBuf;

use crate::error::{Error, Result};
use crate::runtime::rng::Rng;
use crate::sim::scenario::Scenario;
use crate::util::json::Json;

/// The three fine-tuning schemes evaluated in the paper (§V).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Scheme {
    /// Classic single-device adapter fine-tuning, all adapters unfrozen.
    Single,
    /// Pipeline-parallel adapter fine-tuning, all adapters always unfrozen,
    /// PipeDream-style weight stashing (the staleness/memory baseline).
    PipeAdapter,
    /// The paper's contribution: ring pipeline + scheduled top-down
    /// unfreezing + early-stopped backprop, no weight versioning.
    RingAda,
}

impl Scheme {
    pub const ALL: [Scheme; 3] = [Scheme::Single, Scheme::PipeAdapter, Scheme::RingAda];

    pub fn name(&self) -> &'static str {
        match self {
            Scheme::Single => "Single",
            Scheme::PipeAdapter => "PipeAdapter",
            Scheme::RingAda => "RingAda",
        }
    }
}

/// One edge device's capabilities, as uploaded to the coordinator in the
/// paper's initialization stage: `(R_u, C_u^comp, C_u^mem)`.
#[derive(Debug, Clone)]
pub struct DeviceSpec {
    /// Stable identifier (index into the cluster).
    pub id: usize,
    /// Relative computational speed `C_u^comp` (1.0 = the device the LUT
    /// was profiled on; 0.5 = half as fast).
    pub compute_speed: f64,
    /// Memory budget `C_u^mem` in bytes.
    pub mem_bytes: usize,
    /// Correlated-failure domain label (rack / NAT group) for the world
    /// model's domain outages (see [`crate::world`]).  `None` = unlabeled;
    /// the JSON form omits the key, so pre-world configs and goldens are
    /// untouched.
    pub domain: Option<String>,
}

impl DeviceSpec {
    pub fn uniform(id: usize) -> Self {
        DeviceSpec { id, compute_speed: 1.0, mem_bytes: 8 << 30, domain: None }
    }
}

/// Parse an RNG seed from JSON.  Seeds are serialized as *strings*: JSON
/// numbers are f64, which silently corrupts u64 seeds ≥ 2^53 — fatal for
/// the replay contract.  Plain numbers stay accepted for hand-written
/// files with small seeds.
fn seed_from_json(v: &Json) -> Result<u64> {
    match v {
        Json::Str(s) => s
            .parse::<u64>()
            .map_err(|_| Error::Config(format!("seed `{s}` is not a u64"))),
        other => other.as_u64(),
    }
}

/// The edge cluster: devices plus the D2D link-rate matrix `R_{u,u'}`.
#[derive(Debug, Clone)]
pub struct ClusterConfig {
    pub devices: Vec<DeviceSpec>,
    /// `rate_bytes_per_s[u][v]` — data rate of the directed link u→v.
    /// Diagonal entries are ignored.
    pub rate_bytes_per_s: Vec<Vec<f64>>,
    /// Per-message fixed latency (seconds) of the D2D links.
    pub link_latency_s: f64,
}

impl ClusterConfig {
    /// `n` identical devices, fully connected at `rate` bytes/s.
    pub fn homogeneous(n: usize, rate: f64) -> Self {
        ClusterConfig {
            devices: (0..n).map(DeviceSpec::uniform).collect(),
            rate_bytes_per_s: vec![vec![rate; n]; n],
            link_latency_s: 2e-3,
        }
    }

    /// The paper's 4-device setup with mildly heterogeneous compute
    /// (the Trm assignment 4:5:2:3 in Fig. 2 implies unequal capability).
    ///
    /// Speeds are *relative to the machine the LUT was profiled on* and are
    /// set an order of magnitude below it: the paper targets edge devices
    /// whose per-layer compute dominates the ~200 Mbps D2D link time (§V:
    /// computation time is profiled "by scaling the computational speed").
    pub fn paper_default() -> Self {
        let mut c = Self::homogeneous(4, 25e6); // ~200 Mbps D2D links
        let speeds = [0.10, 0.125, 0.05, 0.075];
        for (d, s) in c.devices.iter_mut().zip(speeds) {
            d.compute_speed = s;
            d.mem_bytes = 6 << 30;
        }
        c
    }

    /// Seed-deterministic synthetic edge cluster for the scale experiments
    /// (`examples/big_ring.rs`, `benches/scale.rs`): `n` devices at
    /// paper-class speeds with a `heterogeneity`-controlled spread, fully
    /// connected by ~200 Mbps D2D links whose rates jitter by the same
    /// knob.
    ///
    /// `heterogeneity` must be a positive finite number (values above 1
    /// clamp to 1): 1 ⇒ up to ~10× compute spread (log-uniform, strictly
    /// positive) and up to 5× link-rate spread.  Same
    /// `(n, seed, heterogeneity)` ⇒ bit-identical cluster.
    ///
    /// NaN, negative, and zero heterogeneity are rejected with
    /// [`Error::Schedule`] — a zero-spread "synthetic" pool is an
    /// identical-device pool in disguise; ask [`ClusterConfig::homogeneous`]
    /// for that.  `n == 0` is rejected for the same reason `validate`
    /// rejects it.
    pub fn synthetic(n: usize, seed: u64, heterogeneity: f64) -> Result<Self> {
        if n == 0 {
            return Err(Error::Schedule(
                "synthetic cluster needs at least one device".into(),
            ));
        }
        // `!(h > 0.0)` also catches NaN, which `h <= 0.0` lets through.
        if !(heterogeneity > 0.0) || !heterogeneity.is_finite() {
            return Err(Error::Schedule(format!(
                "synthetic heterogeneity {heterogeneity} must be finite and > 0"
            )));
        }
        Ok(Self::synthetic_raw(n, seed, heterogeneity))
    }

    /// Infallible body of [`ClusterConfig::synthetic`] for in-crate
    /// callers whose inputs are compile-time constants.
    pub(crate) fn synthetic_raw(n: usize, seed: u64, heterogeneity: f64) -> Self {
        let h = heterogeneity.clamp(0.0, 1.0);
        let mut rng = Rng::new(seed ^ 0xC1_05_7E_12);
        let mut c = Self::homogeneous(n, 25e6);
        for d in &mut c.devices {
            // Log-uniform spread around the paper-class 0.1 relative speed.
            let spread = 2.0 * rng.next_f64() - 1.0; // [-1, 1)
            d.compute_speed = 0.1 * 10f64.powf(0.5 * h * spread);
            d.mem_bytes = 6 << 30;
        }
        for i in 0..n {
            for j in 0..n {
                if i != j {
                    c.rate_bytes_per_s[i][j] = 25e6 * (1.0 - 0.8 * h * rng.next_f64());
                }
            }
        }
        c
    }

    pub fn len(&self) -> usize {
        self.devices.len()
    }

    pub fn is_empty(&self) -> bool {
        self.devices.is_empty()
    }

    pub fn validate(&self) -> Result<()> {
        let n = self.devices.len();
        if n == 0 {
            return Err(Error::Config("cluster has no devices".into()));
        }
        if self.rate_bytes_per_s.len() != n
            || self.rate_bytes_per_s.iter().any(|r| r.len() != n)
        {
            return Err(Error::Config(format!(
                "rate matrix must be {n}x{n}"
            )));
        }
        for (i, d) in self.devices.iter().enumerate() {
            if d.id != i {
                return Err(Error::Config(format!(
                    "device ids must be 0..n in order (got id {} at index {i})",
                    d.id
                )));
            }
            // `!(x > 0.0)` also catches NaN, which `x <= 0.0` lets through.
            if !(d.compute_speed > 0.0) || !d.compute_speed.is_finite() {
                return Err(Error::Config(format!(
                    "device {i} has non-positive or non-finite speed {}",
                    d.compute_speed
                )));
            }
        }
        for i in 0..n {
            for j in 0..n {
                if i == j {
                    continue;
                }
                let r = self.rate_bytes_per_s[i][j];
                if !(r > 0.0) || !r.is_finite() {
                    return Err(Error::Config(format!(
                        "link {i}->{j} has non-positive or non-finite rate {r}"
                    )));
                }
            }
        }
        if !self.link_latency_s.is_finite() || self.link_latency_s < 0.0 {
            return Err(Error::Config(format!(
                "link latency {} must be finite and >= 0",
                self.link_latency_s
            )));
        }
        Ok(())
    }

    /// Parse a cluster from JSON.  Two forms are accepted: the explicit
    /// device/rate-matrix object the `ExperimentConfig` format has always
    /// used, and a compact `{"synthetic": {"n", "seed", "heterogeneity"}}`
    /// spec that expands through [`ClusterConfig::synthetic`] — fleet pools
    /// of 128+ devices are described in one line instead of a 128×128
    /// matrix.
    pub fn from_json(v: &Json) -> Result<Self> {
        if let Some(s) = v.get("synthetic") {
            return Self::synthetic(
                s.req("n")?.as_usize()?,
                seed_from_json(s.req("seed")?)?,
                s.req("heterogeneity")?.as_f64()?,
            );
        }
        let devices = v
            .req("devices")?
            .as_arr()?
            .iter()
            .map(|d| {
                Ok(DeviceSpec {
                    id: d.req("id")?.as_usize()?,
                    compute_speed: d.req("compute_speed")?.as_f64()?,
                    mem_bytes: d.req("mem_bytes")?.as_usize()?,
                    domain: match d.get("domain") {
                        Some(dm) => Some(dm.as_str()?.to_string()),
                        None => None,
                    },
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let rate_bytes_per_s = v
            .req("rate_bytes_per_s")?
            .as_arr()?
            .iter()
            .map(Json::f64_vec)
            .collect::<Result<Vec<_>>>()?;
        Ok(ClusterConfig {
            devices,
            rate_bytes_per_s,
            link_latency_s: v.req("link_latency_s")?.as_f64()?,
        })
    }

    /// Serialize in the explicit form.  f64 fields round-trip bit-exactly
    /// (shortest round-trip printing); integer fields pass through JSON
    /// numbers and so are exact up to 2^53 — far above any real device id
    /// or memory budget, but not a blanket guarantee.
    pub fn to_json(&self) -> Json {
        let devices = Json::Arr(
            self.devices
                .iter()
                .map(|d| {
                    let mut pairs = vec![
                        ("id", Json::num(d.id as f64)),
                        ("compute_speed", Json::num(d.compute_speed)),
                        ("mem_bytes", Json::num(d.mem_bytes as f64)),
                    ];
                    // Omitted when unlabeled so pre-world JSON and the
                    // golden fingerprints stay byte-identical.
                    if let Some(dm) = &d.domain {
                        pairs.push(("domain", Json::str(dm.clone())));
                    }
                    Json::obj(pairs)
                })
                .collect(),
        );
        let rates = Json::Arr(
            self.rate_bytes_per_s
                .iter()
                .map(|r| Json::arr_f64(r))
                .collect(),
        );
        Json::obj(vec![
            ("devices", devices),
            ("rate_bytes_per_s", rates),
            ("link_latency_s", Json::num(self.link_latency_s)),
        ])
    }
}

/// Training hyperparameters (paper §V + Algorithm 1 inputs).
#[derive(Debug, Clone)]
pub struct TrainingConfig {
    /// Total training rounds (a round = every client has been initiator once;
    /// this is the paper's "epoch" axis in Fig. 3).
    pub rounds: usize,
    /// Local iterations `I` per initiator per round.
    pub local_iters: usize,
    /// Layer-unfreezing interval `k`: every `k` rounds, `d ← d+1`
    /// (paper: "for every 40 steps, we unfreeze the next adapter").
    pub unfreeze_interval: usize,
    /// Initial unfreeze depth (paper: head + top-most adapter = 1).
    pub initial_depth: usize,
    /// Adam learning rate for adapters + head.
    pub lr: f32,
    /// Convergence: stop when the loss EMA improves by less than
    /// `convergence_tol` for `convergence_patience` consecutive rounds.
    pub convergence_tol: f32,
    pub convergence_patience: usize,
    /// RNG seed for weights + data.
    pub seed: u64,
}

impl Default for TrainingConfig {
    fn default() -> Self {
        TrainingConfig {
            rounds: 50,
            local_iters: 4,
            unfreeze_interval: 10,
            initial_depth: 1,
            // 4e-3 is stable for every scheme including the delayed-update
            // PipeAdapter baseline (1e-2 oscillates under staleness).
            lr: 4e-3,
            convergence_tol: 1e-3,
            convergence_patience: 8,
            seed: 42,
        }
    }
}

impl TrainingConfig {
    pub fn validate(&self) -> Result<()> {
        if self.rounds == 0 || self.local_iters == 0 {
            return Err(Error::Config("rounds and local_iters must be > 0".into()));
        }
        if self.unfreeze_interval == 0 {
            return Err(Error::Config("unfreeze_interval must be > 0".into()));
        }
        if self.initial_depth == 0 {
            return Err(Error::Config(
                "initial_depth must be >= 1 (head + top adapter)".into(),
            ));
        }
        if !(self.lr > 0.0) {
            return Err(Error::Config("lr must be positive".into()));
        }
        Ok(())
    }
}

/// A complete experiment description.
#[derive(Debug, Clone)]
pub struct ExperimentConfig {
    /// Directory containing `manifest.json` + `*.hlo.txt` for one model
    /// config (e.g. `artifacts/tiny`).
    pub artifact_dir: PathBuf,
    pub cluster: ClusterConfig,
    pub training: TrainingConfig,
    /// Synthetic-QA dataset size per device.
    pub samples_per_device: usize,
    /// Held-out eval set size (global).
    pub eval_samples: usize,
    /// Optional fault/heterogeneity script applied to the simulated clock
    /// (see [`crate::sim::scenario`] for the spec format).  `None` = the
    /// healthy cluster the paper evaluates.
    pub scenario: Option<Scenario>,
}

impl ExperimentConfig {
    /// The paper's default 4-device setup over the given artifact dir.
    pub fn paper_default(artifact_dir: impl Into<PathBuf>) -> Self {
        ExperimentConfig {
            artifact_dir: artifact_dir.into(),
            cluster: ClusterConfig::paper_default(),
            training: TrainingConfig::default(),
            samples_per_device: 256,
            eval_samples: 128,
            scenario: None,
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.cluster.validate()?;
        self.training.validate()?;
        if self.samples_per_device == 0 {
            return Err(Error::Config("samples_per_device must be > 0".into()));
        }
        if let Some(sc) = &self.scenario {
            sc.validate(self.cluster.len())?;
        }
        Ok(())
    }

    pub fn from_json_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_json(&Json::parse(&text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let tr = v.req("training")?;
        Ok(ExperimentConfig {
            artifact_dir: PathBuf::from(v.req("artifact_dir")?.as_str()?),
            cluster: ClusterConfig::from_json(v.req("cluster")?)?,
            training: TrainingConfig {
                rounds: tr.req("rounds")?.as_usize()?,
                local_iters: tr.req("local_iters")?.as_usize()?,
                unfreeze_interval: tr.req("unfreeze_interval")?.as_usize()?,
                initial_depth: tr.req("initial_depth")?.as_usize()?,
                lr: tr.req("lr")?.as_f32()?,
                convergence_tol: tr.req("convergence_tol")?.as_f32()?,
                convergence_patience: tr.req("convergence_patience")?.as_usize()?,
                seed: tr.req("seed")?.as_u64()?,
            },
            samples_per_device: v.req("samples_per_device")?.as_usize()?,
            eval_samples: v.req("eval_samples")?.as_usize()?,
            scenario: match v.get("scenario") {
                Some(s) => Some(Scenario::from_json(s)?),
                None => None,
            },
        })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            (
                "artifact_dir",
                Json::str(self.artifact_dir.to_string_lossy().to_string()),
            ),
            ("cluster", self.cluster.to_json()),
            (
                "training",
                Json::obj(vec![
                    ("rounds", Json::num(self.training.rounds as f64)),
                    ("local_iters", Json::num(self.training.local_iters as f64)),
                    (
                        "unfreeze_interval",
                        Json::num(self.training.unfreeze_interval as f64),
                    ),
                    ("initial_depth", Json::num(self.training.initial_depth as f64)),
                    ("lr", Json::num(self.training.lr as f64)),
                    (
                        "convergence_tol",
                        Json::num(self.training.convergence_tol as f64),
                    ),
                    (
                        "convergence_patience",
                        Json::num(self.training.convergence_patience as f64),
                    ),
                    ("seed", Json::num(self.training.seed as f64)),
                ]),
            ),
            (
                "samples_per_device",
                Json::num(self.samples_per_device as f64),
            ),
            ("eval_samples", Json::num(self.eval_samples as f64)),
        ];
        if let Some(sc) = &self.scenario {
            pairs.push(("scenario", sc.to_json()));
        }
        Json::obj(pairs)
    }
}

/// How the fleet scheduler admission-controls the waiting queue.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum AdmissionControl {
    /// Every job eventually gets a ring if the pool can host one (the
    /// pre-admission-control behavior; the legacy differential path
    /// requires it).
    Open,
    /// The policy may permanently reject a not-yet-started job whose
    /// *estimated best-case* finish (planner bottleneck estimate over the
    /// pool's fastest alive devices — a heuristic shed threshold, not a
    /// proof of infeasibility) already misses its deadline.  Rejected
    /// jobs count as deadline misses — rejection sheds load, it does not
    /// launder the hit-rate metric.
    Feasibility,
}

impl AdmissionControl {
    pub fn name(&self) -> &'static str {
        match self {
            AdmissionControl::Open => "open",
            AdmissionControl::Feasibility => "feasibility",
        }
    }

    fn from_str(s: &str) -> Result<Self> {
        match s {
            "open" => Ok(AdmissionControl::Open),
            "feasibility" => Ok(AdmissionControl::Feasibility),
            other => Err(Error::Config(format!(
                "admission `{other}` is not one of: open, feasibility"
            ))),
        }
    }
}

/// A multi-tenant serving experiment (the `fleet` subsystem): one shared
/// edge-device pool, a seed-deterministic synthetic job stream, and an
/// optional pool-level fault scenario.  Same `seed` ⇒ identical trace ⇒
/// byte-identical `FleetReport` (the fleet determinism property).
#[derive(Debug, Clone)]
pub struct FleetConfig {
    /// The shared device pool every job's ring is carved from.
    pub pool: ClusterConfig,
    /// Jobs in the synthetic arrival trace.
    pub jobs: usize,
    /// Mean of the exponential inter-arrival gap (Poisson-like arrivals).
    pub mean_interarrival_s: f64,
    /// Seed for the trace generator and the per-job training seeds.
    pub seed: u64,
    /// Per-job model-size range in transformer blocks (inclusive).  The
    /// floor is 4: ring requests need at least 2 blocks per position.
    pub min_layers: usize,
    pub max_layers: usize,
    /// Per-job epoch-budget range in rounds (inclusive).
    pub min_rounds: usize,
    pub max_rounds: usize,
    /// Local iterations per initiator turn, uniform across jobs.
    pub local_iters: usize,
    /// Optional pool-level fault script: a dropout hits whichever job holds
    /// the device (triggering its re-plan path) or shrinks the free pool.
    pub scenario: Option<Scenario>,
    /// Priority-class weights `[high, normal, low]` for the synthetic
    /// trace (normalized internally; need not sum to 1).
    pub priority_mix: [f64; 3],
    /// Allow preemption-capable policies to pause lower-priority running
    /// jobs at round boundaries and reclaim their devices.  Off by
    /// default: the legacy differential path has no pause mechanism.
    pub preemption: bool,
    /// Admission-control mode (see [`AdmissionControl`]).
    pub admission: AdmissionControl,
    /// Optional versioned JSONL trace to serve instead of the synthetic
    /// generator (see `fleet::JsonlSource`).  The synthetic knobs above
    /// still size per-job training; `jobs` is ignored when a trace is set
    /// (the stream ends when the file does).
    pub trace_path: Option<String>,
    /// Optional inline world-event timeline (see [`crate::world`]):
    /// correlated domain outages, device joins, energy/memory budgets,
    /// diurnal arrival intensity.  An event-free world is the degenerate
    /// world — byte-identical trajectories to `None`.
    pub world: Option<crate::world::World>,
    /// Optional `ringada_world` v1 JSONL trace to load the world from
    /// instead (mutually exclusive with `world`; see
    /// [`FleetConfig::resolve_world`]).
    pub world_trace_path: Option<String>,
    /// Fork-join worker count for the serve loop and its ring planning
    /// (see [`crate::exec`]).  `1` (the default, and the only value legacy
    /// configs can express) is the fully sequential code path; the
    /// `RINGADA_THREADS` env var overrides any value set here.  Thread
    /// count never changes serve results, only wall clock.
    pub threads: usize,
    /// Enable the cross-job planning pipeline: plan requests pending at
    /// the same fleet timestamp (admissions, dropout re-plans, resize
    /// re-plans) are deduplicated by plan-cache key and fanned out over
    /// the fork-join pool, with results committed in heap-pop order.
    /// Off by default — the legacy one-plan-per-event path.  Like
    /// `threads`, a wall-clock knob: serve results are byte-identical
    /// either way, except that [`crate::metrics::FleetReport`] gains an
    /// append-only `planning` observability section when enabled.
    pub plan_pipeline: bool,
    /// Enable speculative pre-planning on top of `plan_pipeline`: between
    /// event barriers the fleet plans against the profiles of imminent
    /// arrivals and queued re-admissions so the cache is warm when the
    /// event fires.  Speculation only ever inserts cache entries
    /// identical to what the demand path would compute, so it is
    /// on/off- and thread-count-invariant by construction.  Requires
    /// `plan_pipeline`.
    pub speculate: bool,
}

impl FleetConfig {
    /// Synthetic fleet over a [`ClusterConfig::synthetic`] pool with
    /// paper-class job sizes — the examples/benches/tests entry point.
    pub fn synthetic(pool_devices: usize, jobs: usize, seed: u64) -> Self {
        FleetConfig {
            pool: ClusterConfig::synthetic_raw(pool_devices, seed, 0.6),
            jobs,
            mean_interarrival_s: 20.0,
            seed,
            min_layers: 8,
            max_layers: 24,
            min_rounds: 2,
            max_rounds: 4,
            local_iters: 1,
            scenario: None,
            priority_mix: [0.2, 0.5, 0.3],
            preemption: false,
            admission: AdmissionControl::Open,
            trace_path: None,
            world: None,
            world_trace_path: None,
            threads: 1,
            plan_pipeline: false,
            speculate: false,
        }
    }

    /// The world this config asks for, or `None` for the fixed-pool
    /// default.  An *event-free* world also resolves to `None`: the
    /// degenerate world is indistinguishable from no world, and mapping
    /// it out here keeps every healthy-path trajectory (and snapshot)
    /// byte-identical by construction.
    pub fn resolve_world(&self) -> Result<Option<crate::world::World>> {
        if self.world.is_some() && self.world_trace_path.is_some() {
            return Err(Error::Config(
                "set `world` or `world_trace_path`, not both".into(),
            ));
        }
        let world = match (&self.world, &self.world_trace_path) {
            (Some(w), _) => Some(w.clone()),
            (None, Some(path)) => Some(crate::world::World::load(path)?),
            (None, None) => None,
        };
        match world {
            Some(w) if w.is_empty() => Ok(None),
            other => Ok(other),
        }
    }

    pub fn validate(&self) -> Result<()> {
        self.pool.validate()?;
        let mix_sum: f64 = self.priority_mix.iter().sum();
        if self.priority_mix.iter().any(|w| !w.is_finite() || *w < 0.0) || !(mix_sum > 0.0) {
            return Err(Error::Config(format!(
                "priority_mix {:?} must be finite, non-negative, and sum > 0",
                self.priority_mix
            )));
        }
        if self.jobs == 0 {
            return Err(Error::Config("fleet needs at least one job".into()));
        }
        if !self.mean_interarrival_s.is_finite() || self.mean_interarrival_s <= 0.0 {
            return Err(Error::Config(format!(
                "mean_interarrival_s {} must be finite and > 0",
                self.mean_interarrival_s
            )));
        }
        if self.min_layers < 4 || self.max_layers < self.min_layers {
            return Err(Error::Config(format!(
                "layer range [{}, {}] invalid (min 4, min <= max)",
                self.min_layers, self.max_layers
            )));
        }
        if self.min_rounds == 0 || self.max_rounds < self.min_rounds {
            return Err(Error::Config(format!(
                "round range [{}, {}] invalid (min 1, min <= max)",
                self.min_rounds, self.max_rounds
            )));
        }
        if self.local_iters == 0 {
            return Err(Error::Config("local_iters must be > 0".into()));
        }
        if self.threads == 0 {
            return Err(Error::Config(
                "threads must be >= 1 (use 1 for sequential)".into(),
            ));
        }
        if self.speculate && !self.plan_pipeline {
            return Err(Error::Config(
                "speculate requires plan_pipeline (speculation pre-warms the pipeline's \
                 plan cache; there is nothing to speculate for without it)"
                    .into(),
            ));
        }
        if let Some(sc) = &self.scenario {
            sc.validate(self.pool.len())?;
        }
        if self.world.is_some() && self.world_trace_path.is_some() {
            return Err(Error::Config(
                "set `world` or `world_trace_path`, not both".into(),
            ));
        }
        if let Some(w) = &self.world {
            w.validate(self.pool.len())?;
        }
        // `world_trace_path` is validated at load time (resolve_world):
        // validate() stays IO-free, like `trace_path`.
        Ok(())
    }

    pub fn from_json_file(path: impl AsRef<std::path::Path>) -> Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let cfg = Self::from_json(&Json::parse(&text)?)?;
        cfg.validate()?;
        Ok(cfg)
    }

    pub fn from_json(v: &Json) -> Result<Self> {
        let seed = seed_from_json(v.req("seed")?)?;
        // Serving knobs are optional so pre-existing fleet JSON keeps
        // parsing with the legacy behavior (all-default: open admission,
        // no preemption, the default priority mix).
        let priority_mix = match v.get("priority_mix") {
            Some(m) => {
                let ws = m.f64_vec()?;
                if ws.len() != 3 {
                    return Err(Error::Config(format!(
                        "priority_mix must have exactly 3 weights [high, normal, low], got {}",
                        ws.len()
                    )));
                }
                [ws[0], ws[1], ws[2]]
            }
            None => [0.2, 0.5, 0.3],
        };
        let preemption = match v.get("preemption") {
            Some(b) => b.as_bool()?,
            None => false,
        };
        let admission = match v.get("admission") {
            Some(a) => AdmissionControl::from_str(a.as_str()?)?,
            None => AdmissionControl::Open,
        };
        Ok(FleetConfig {
            pool: ClusterConfig::from_json(v.req("pool")?)?,
            jobs: v.req("jobs")?.as_usize()?,
            mean_interarrival_s: v.req("mean_interarrival_s")?.as_f64()?,
            seed,
            min_layers: v.req("min_layers")?.as_usize()?,
            max_layers: v.req("max_layers")?.as_usize()?,
            min_rounds: v.req("min_rounds")?.as_usize()?,
            max_rounds: v.req("max_rounds")?.as_usize()?,
            local_iters: v.req("local_iters")?.as_usize()?,
            scenario: match v.get("scenario") {
                Some(s) => Some(Scenario::from_json(s)?),
                None => None,
            },
            priority_mix,
            preemption,
            admission,
            trace_path: match v.get("trace_path") {
                Some(p) => Some(p.as_str()?.to_string()),
                None => None,
            },
            world: match v.get("world") {
                Some(w) => Some(crate::world::World::from_json(w)?),
                None => None,
            },
            world_trace_path: match v.get("world_trace_path") {
                Some(p) => Some(p.as_str()?.to_string()),
                None => None,
            },
            // Optional like the serving knobs: absent means sequential.
            // `as_usize` already rejects negative, fractional, and
            // oversized numbers; zero gets the field-contextual error
            // here rather than a late one from validate().
            threads: match v.get("threads") {
                Some(t) => {
                    let n = t
                        .as_usize()
                        .map_err(|e| Error::Config(format!("threads: {e}")))?;
                    if n == 0 {
                        return Err(Error::Config(
                            "threads must be >= 1 (use 1 for sequential)".into(),
                        ));
                    }
                    n
                }
                None => 1,
            },
            // Optional like `threads`: absent means the legacy
            // one-plan-per-event path.  `speculate` without
            // `plan_pipeline` is rejected by validate().
            plan_pipeline: match v.get("plan_pipeline") {
                Some(b) => b
                    .as_bool()
                    .map_err(|e| Error::Config(format!("plan_pipeline: {e}")))?,
                None => false,
            },
            speculate: match v.get("speculate") {
                Some(b) => b
                    .as_bool()
                    .map_err(|e| Error::Config(format!("speculate: {e}")))?,
                None => false,
            },
        })
    }

    pub fn to_json(&self) -> Json {
        let mut pairs = vec![
            ("pool", self.pool.to_json()),
            ("jobs", Json::num(self.jobs as f64)),
            (
                "mean_interarrival_s",
                Json::num(self.mean_interarrival_s),
            ),
            // String, not number: u64 seeds don't fit f64 (see from_json).
            ("seed", Json::str(self.seed.to_string())),
            ("min_layers", Json::num(self.min_layers as f64)),
            ("max_layers", Json::num(self.max_layers as f64)),
            ("min_rounds", Json::num(self.min_rounds as f64)),
            ("max_rounds", Json::num(self.max_rounds as f64)),
            ("local_iters", Json::num(self.local_iters as f64)),
            ("priority_mix", Json::arr_f64(&self.priority_mix)),
            ("preemption", Json::Bool(self.preemption)),
            ("admission", Json::str(self.admission.name())),
        ];
        if let Some(sc) = &self.scenario {
            pairs.push(("scenario", sc.to_json()));
        }
        if let Some(path) = &self.trace_path {
            pairs.push(("trace_path", Json::str(path)));
        }
        if let Some(w) = &self.world {
            pairs.push(("world", w.to_json()));
        }
        if let Some(path) = &self.world_trace_path {
            pairs.push(("world_trace_path", Json::str(path)));
        }
        // Emitted only when non-default so legacy round-trips stay
        // byte-identical (threads is a runtime knob, not trace state).
        if self.threads != 1 {
            pairs.push(("threads", Json::num(self.threads as f64)));
        }
        if self.plan_pipeline {
            pairs.push(("plan_pipeline", Json::Bool(true)));
        }
        if self.speculate {
            pairs.push(("speculate", Json::Bool(true)));
        }
        Json::obj(pairs)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn paper_default_validates() {
        ExperimentConfig::paper_default("artifacts/tiny").validate().unwrap();
    }

    #[test]
    fn homogeneous_cluster_shape() {
        let c = ClusterConfig::homogeneous(5, 1e6);
        assert_eq!(c.len(), 5);
        c.validate().unwrap();
    }

    #[test]
    fn rejects_bad_rate_matrix() {
        let mut c = ClusterConfig::homogeneous(3, 1e6);
        c.rate_bytes_per_s.pop();
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_zero_speed() {
        let mut c = ClusterConfig::homogeneous(2, 1e6);
        c.devices[1].compute_speed = 0.0;
        assert!(c.validate().is_err());
    }

    #[test]
    fn rejects_nan_speed_and_nan_or_zero_rates() {
        let mut c = ClusterConfig::homogeneous(2, 1e6);
        c.devices[0].compute_speed = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = ClusterConfig::homogeneous(2, 1e6);
        c.rate_bytes_per_s[0][1] = 0.0;
        assert!(c.validate().is_err());
        let mut c = ClusterConfig::homogeneous(2, 1e6);
        c.rate_bytes_per_s[1][0] = f64::NAN;
        assert!(c.validate().is_err());
        let mut c = ClusterConfig::homogeneous(2, 1e6);
        c.link_latency_s = f64::INFINITY;
        assert!(c.validate().is_err());
    }

    #[test]
    fn synthetic_cluster_is_deterministic_and_valid() {
        let a = ClusterConfig::synthetic(64, 9, 0.8).unwrap();
        let b = ClusterConfig::synthetic(64, 9, 0.8).unwrap();
        a.validate().unwrap();
        assert_eq!(a.len(), 64);
        for (da, db) in a.devices.iter().zip(&b.devices) {
            assert_eq!(da.compute_speed.to_bits(), db.compute_speed.to_bits());
        }
        assert_eq!(a.rate_bytes_per_s, b.rate_bytes_per_s);
        // Different seeds produce different clusters.
        let c = ClusterConfig::synthetic(64, 10, 0.8).unwrap();
        assert!(a
            .devices
            .iter()
            .zip(&c.devices)
            .any(|(x, y)| x.compute_speed != y.compute_speed));
    }

    #[test]
    fn synthetic_cluster_rejects_degenerate_inputs() {
        // NaN, negative, and zero heterogeneity, plus an empty pool, are
        // schedule errors — not silently-degenerate pools.
        for h in [f64::NAN, -0.5, 0.0, f64::NEG_INFINITY, f64::INFINITY] {
            let err = ClusterConfig::synthetic(8, 3, h).unwrap_err();
            assert!(
                matches!(err, Error::Schedule(_)),
                "heterogeneity {h} should be Error::Schedule, got {err}"
            );
        }
        let err = ClusterConfig::synthetic(0, 3, 0.5).unwrap_err();
        assert!(matches!(err, Error::Schedule(_)), "n=0 should be Error::Schedule");
        // Values above 1 still clamp rather than error (documented).
        let clamped = ClusterConfig::synthetic(4, 3, 7.0).unwrap();
        let unit = ClusterConfig::synthetic(4, 3, 1.0).unwrap();
        for (a, b) in clamped.devices.iter().zip(&unit.devices) {
            assert_eq!(a.compute_speed.to_bits(), b.compute_speed.to_bits());
        }
        // The JSON synthetic spec propagates the same rejection.
        let text = r#"{"synthetic": {"n": 8, "seed": 3, "heterogeneity": 0}}"#;
        assert!(ClusterConfig::from_json(&Json::parse(text).unwrap()).is_err());
        let text = r#"{"synthetic": {"n": 0, "seed": 3, "heterogeneity": 0.5}}"#;
        assert!(ClusterConfig::from_json(&Json::parse(text).unwrap()).is_err());
    }

    #[test]
    fn rejects_zero_depth() {
        let mut t = TrainingConfig::default();
        t.initial_depth = 0;
        assert!(t.validate().is_err());
    }

    #[test]
    fn config_round_trips_through_json() {
        let cfg = ExperimentConfig::paper_default("artifacts/tiny");
        let json = cfg.to_json().pretty();
        let back =
            ExperimentConfig::from_json(&crate::util::json::Json::parse(&json).unwrap()).unwrap();
        back.validate().unwrap();
        assert_eq!(back.cluster.len(), 4);
        assert_eq!(back.training.seed, cfg.training.seed);
        assert_eq!(back.cluster.devices[2].compute_speed, 0.05);
    }

    #[test]
    fn scenario_rides_along_in_experiment_json() {
        let mut cfg = ExperimentConfig::paper_default("artifacts/tiny");
        cfg.scenario = Some(crate::sim::Scenario::synth(11, 4, 500.0, 0.8));
        cfg.validate().unwrap();
        let json = cfg.to_json().pretty();
        let back = ExperimentConfig::from_json(&Json::parse(&json).unwrap()).unwrap();
        assert_eq!(back.scenario, cfg.scenario);
        // A scenario referencing devices outside the cluster fails validate.
        let mut bad = ExperimentConfig::paper_default("artifacts/tiny");
        bad.scenario = Some(crate::sim::Scenario {
            name: "bad".into(),
            events: vec![crate::sim::ScenarioEvent::Dropout { device: 9, at: 1.0 }],
        });
        assert!(bad.validate().is_err());
    }

    #[test]
    fn scheme_names() {
        assert_eq!(Scheme::RingAda.name(), "RingAda");
        assert_eq!(Scheme::ALL.len(), 3);
    }

    #[test]
    fn cluster_json_round_trips_bit_exactly() {
        let c = ClusterConfig::synthetic(6, 5, 0.7).unwrap();
        let back = ClusterConfig::from_json(&Json::parse(&c.to_json().pretty()).unwrap()).unwrap();
        back.validate().unwrap();
        for (a, b) in c.devices.iter().zip(&back.devices) {
            assert_eq!(a.compute_speed.to_bits(), b.compute_speed.to_bits());
            assert_eq!(a.mem_bytes, b.mem_bytes);
        }
        assert_eq!(c.rate_bytes_per_s, back.rate_bytes_per_s);
    }

    #[test]
    fn cluster_json_accepts_synthetic_spec() {
        let text = r#"{"synthetic": {"n": 16, "seed": 9, "heterogeneity": 0.8}}"#;
        let c = ClusterConfig::from_json(&Json::parse(text).unwrap()).unwrap();
        c.validate().unwrap();
        assert_eq!(c.len(), 16);
        let direct = ClusterConfig::synthetic(16, 9, 0.8).unwrap();
        for (a, b) in c.devices.iter().zip(&direct.devices) {
            assert_eq!(a.compute_speed.to_bits(), b.compute_speed.to_bits());
        }
        // String seeds are accepted here too, so > 2^53 seeds survive.
        let text = r#"{"synthetic": {"n": 4, "seed": "1152921504606846977", "heterogeneity": 0.2}}"#;
        let c2 = ClusterConfig::from_json(&Json::parse(text).unwrap()).unwrap();
        let d2 = ClusterConfig::synthetic(4, (1u64 << 60) + 1, 0.2).unwrap();
        assert_eq!(
            c2.devices[0].compute_speed.to_bits(),
            d2.devices[0].compute_speed.to_bits()
        );
    }

    #[test]
    fn fleet_config_validates_and_round_trips() {
        let mut cfg = FleetConfig::synthetic(8, 6, 11);
        cfg.scenario = Some(crate::sim::Scenario::synth(11, 8, 500.0, 0.5));
        cfg.priority_mix = [0.5, 0.25, 0.25];
        cfg.preemption = true;
        cfg.admission = AdmissionControl::Feasibility;
        cfg.validate().unwrap();
        let back = FleetConfig::from_json(&Json::parse(&cfg.to_json().pretty()).unwrap()).unwrap();
        back.validate().unwrap();
        assert_eq!(back.jobs, cfg.jobs);
        assert_eq!(back.seed, cfg.seed);
        assert_eq!(back.pool.len(), cfg.pool.len());
        assert_eq!(back.scenario, cfg.scenario);
        assert_eq!(
            back.mean_interarrival_s.to_bits(),
            cfg.mean_interarrival_s.to_bits()
        );
        assert_eq!(back.priority_mix, cfg.priority_mix);
        assert!(back.preemption);
        assert_eq!(back.admission, AdmissionControl::Feasibility);
        // Old fleet JSON without the serving knobs still parses, with the
        // legacy defaults.
        let legacy = FleetConfig::synthetic(4, 2, 3);
        let Json::Obj(pairs) = legacy.to_json() else { panic!("fleet json is an object") };
        let n_before = pairs.len();
        let stripped: Vec<(String, Json)> = pairs
            .into_iter()
            .filter(|(k, _)| !matches!(k.as_str(), "priority_mix" | "preemption" | "admission"))
            .collect();
        assert_eq!(stripped.len(), n_before - 3, "all three knobs serialize");
        let back = FleetConfig::from_json(&Json::Obj(stripped)).unwrap();
        back.validate().unwrap();
        assert_eq!(back.priority_mix, [0.2, 0.5, 0.3]);
        assert!(!back.preemption);
        assert_eq!(back.admission, AdmissionControl::Open);
        // Seeds above 2^53 survive the round trip (string-encoded; a JSON
        // number would truncate through f64 and break replayability).
        let mut big = FleetConfig::synthetic(4, 2, (1u64 << 60) + 1);
        big.scenario = None;
        let back = FleetConfig::from_json(&Json::parse(&big.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back.seed, (1u64 << 60) + 1);
    }

    #[test]
    fn fleet_config_rejects_bad_ranges() {
        let mut cfg = FleetConfig::synthetic(4, 4, 1);
        cfg.min_layers = 2; // below the ring-request floor
        assert!(cfg.validate().is_err());
        let mut cfg = FleetConfig::synthetic(4, 4, 1);
        cfg.max_rounds = 0;
        assert!(cfg.validate().is_err());
        let mut cfg = FleetConfig::synthetic(4, 4, 1);
        cfg.mean_interarrival_s = 0.0;
        assert!(cfg.validate().is_err());
        let mut cfg = FleetConfig::synthetic(4, 0, 1);
        cfg.jobs = 0;
        assert!(cfg.validate().is_err());
        // A scenario referencing devices beyond the pool fails validate.
        let mut cfg = FleetConfig::synthetic(4, 4, 1);
        cfg.scenario = Some(crate::sim::Scenario {
            name: "bad".into(),
            events: vec![crate::sim::ScenarioEvent::Dropout { device: 9, at: 1.0 }],
        });
        assert!(cfg.validate().is_err());
        // Degenerate priority mixes are rejected.
        let mut cfg = FleetConfig::synthetic(4, 4, 1);
        cfg.priority_mix = [0.0, 0.0, 0.0];
        assert!(cfg.validate().is_err());
        let mut cfg = FleetConfig::synthetic(4, 4, 1);
        cfg.priority_mix = [0.5, -0.1, 0.6];
        assert!(cfg.validate().is_err());
        let mut cfg = FleetConfig::synthetic(4, 4, 1);
        cfg.priority_mix = [f64::NAN, 0.5, 0.5];
        assert!(cfg.validate().is_err());
        // And a 2- or 4-weight JSON mix fails to parse.
        let mut j = FleetConfig::synthetic(4, 4, 1).to_json();
        if let Json::Obj(pairs) = &mut j {
            for (k, v) in pairs.iter_mut() {
                if k.as_str() == "priority_mix" {
                    *v = Json::arr_f64(&[0.5, 0.5]);
                }
            }
        }
        assert!(FleetConfig::from_json(&j).is_err());
    }

    #[test]
    fn world_rides_along_in_fleet_json() {
        use crate::world::{World, WorldEvent};
        let mut cfg = FleetConfig::synthetic(4, 2, 7);
        cfg.world = Some(World {
            name: "w".into(),
            events: vec![
                WorldEvent::SetDomain { device: 1, domain: "rack".into() },
                WorldEvent::DomainOutage { domain: "rack".into(), at: 50.0 },
            ],
        });
        cfg.validate().unwrap();
        let back = FleetConfig::from_json(&Json::parse(&cfg.to_json().pretty()).unwrap()).unwrap();
        assert_eq!(back.world, cfg.world);
        assert!(back.world_trace_path.is_none());
        // Device domain labels round-trip through the explicit cluster form.
        let mut labeled = ClusterConfig::homogeneous(2, 1e6);
        labeled.devices[1].domain = Some("rack-b".into());
        let back = ClusterConfig::from_json(&Json::parse(&labeled.to_json().pretty()).unwrap())
            .unwrap();
        assert_eq!(back.devices[0].domain, None);
        assert_eq!(back.devices[1].domain.as_deref(), Some("rack-b"));
        // Inline world + trace path is a conflict.
        let mut both = FleetConfig::synthetic(4, 2, 7);
        both.world = Some(World::empty());
        both.world_trace_path = Some("x.jsonl".into());
        assert!(both.validate().is_err());
        assert!(both.resolve_world().is_err());
        // An event-free world resolves to None (the degenerate world).
        let mut empty = FleetConfig::synthetic(4, 2, 7);
        empty.world = Some(World::empty());
        assert!(empty.resolve_world().unwrap().is_none());
        // A world referencing devices beyond the pool fails validate.
        let mut bad = FleetConfig::synthetic(4, 2, 7);
        bad.world = Some(World {
            name: "bad".into(),
            events: vec![WorldEvent::SetDomain { device: 9, domain: "r".into() }],
        });
        assert!(bad.validate().is_err());
    }
}
