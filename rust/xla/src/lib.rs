//! In-tree stand-in for the `xla` PJRT bindings used by the runtime layer.
//!
//! The upstream crate (xla_extension) links a native XLA build that is not
//! available in the offline container this repository targets, so this shim
//! provides the exact API surface `ringada::runtime` consumes:
//!
//! * [`Literal`] is **fully functional** — it round-trips host tensors
//!   (`vec1` / `reshape` / `array_shape` / `to_vec` / `to_tuple`), which is
//!   all the host-side tensor plumbing and its tests need;
//! * [`PjRtClient::buffer_from_host_buffer`] and
//!   [`PjRtBuffer::to_literal_sync`] work (buffers hold literals);
//! * **compilation and execution are stubbed**:
//!   [`HloModuleProto::from_text_file`] and
//!   [`PjRtLoadedExecutable::execute_b`] return [`Error::Unavailable`], so
//!   `Engine::load` fails cleanly with an explanatory message instead of at
//!   link time.  Everything that needs real HLO execution (the numerics
//!   drivers, the device-thread cluster) is gated behind artifact presence
//!   and skips when the artifacts — or this runtime — are missing.
//!
//! Dropping the real bindings back in is a one-line Cargo.toml change; the
//! API is signature-compatible for every call site in this repository.

use std::fmt;

/// True in this shim: HLO parsing/compilation/execution return
/// [`Error::Unavailable`].  Real bindings set this to `false`; gate
/// artifact-driven tests and benches on it (via
/// `ringada::runtime::pjrt_available()`), not just on artifact presence.
pub const STUBBED_RUNTIME: bool = true;

/// Errors surfaced by the (stubbed) PJRT layer.
#[derive(Debug)]
pub enum Error {
    /// The operation needs the native XLA/PJRT runtime, which this offline
    /// build does not link.
    Unavailable(&'static str),
    /// Shape/element-count mismatch in a host-side literal operation.
    Shape(String),
    /// Element-type mismatch in a host-side literal operation.
    ElementType(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Unavailable(what) => {
                write!(f, "{what} (native XLA/PJRT runtime not linked in this offline build)")
            }
            Error::Shape(msg) => write!(f, "literal shape error: {msg}"),
            Error::ElementType(msg) => write!(f, "literal element type error: {msg}"),
        }
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types this stack traffics (f32 / s32 in practice).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum ElementType {
    F32,
    S32,
    S64,
    F64,
    Pred,
}

/// Host element storage behind a [`Literal`].
#[derive(Debug, Clone, PartialEq)]
pub enum Payload {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Payload {
    fn len(&self) -> usize {
        match self {
            Payload::F32(v) => v.len(),
            Payload::I32(v) => v.len(),
        }
    }
}

/// Rust scalar types that map onto an [`ElementType`].
pub trait NativeType: Copy {
    const TY: ElementType;
    fn store(data: &[Self]) -> Payload;
    fn load(payload: &Payload) -> Result<Vec<Self>>;
}

impl NativeType for f32 {
    const TY: ElementType = ElementType::F32;

    fn store(data: &[Self]) -> Payload {
        Payload::F32(data.to_vec())
    }

    fn load(payload: &Payload) -> Result<Vec<Self>> {
        match payload {
            Payload::F32(v) => Ok(v.clone()),
            Payload::I32(_) => Err(Error::ElementType("literal is s32, requested f32".into())),
        }
    }
}

impl NativeType for i32 {
    const TY: ElementType = ElementType::S32;

    fn store(data: &[Self]) -> Payload {
        Payload::I32(data.to_vec())
    }

    fn load(payload: &Payload) -> Result<Vec<Self>> {
        match payload {
            Payload::I32(v) => Ok(v.clone()),
            Payload::F32(_) => Err(Error::ElementType("literal is f32, requested s32".into())),
        }
    }
}

/// Shape of an array literal.
#[derive(Debug, Clone, PartialEq)]
pub struct ArrayShape {
    ty: ElementType,
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }

    pub fn ty(&self) -> ElementType {
        self.ty
    }
}

/// A host-side XLA literal: an n-d array or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    Array {
        ty: ElementType,
        dims: Vec<i64>,
        payload: Payload,
    },
    Tuple(Vec<Literal>),
}

impl Literal {
    /// Rank-1 literal from a host slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        Literal::Array {
            ty: T::TY,
            dims: vec![data.len() as i64],
            payload: T::store(data),
        }
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        match self {
            Literal::Array { ty, payload, .. } => {
                let numel: i64 = dims.iter().product();
                if dims.iter().any(|&d| d < 0) || numel as usize != payload.len() {
                    return Err(Error::Shape(format!(
                        "cannot reshape {} elements to {dims:?}",
                        payload.len()
                    )));
                }
                Ok(Literal::Array {
                    ty: *ty,
                    dims: dims.to_vec(),
                    payload: payload.clone(),
                })
            }
            Literal::Tuple(_) => Err(Error::Shape("cannot reshape a tuple literal".into())),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::Array { ty, dims, .. } => Ok(ArrayShape { ty: *ty, dims: dims.clone() }),
            Literal::Tuple(_) => Err(Error::Shape("tuple literal has no array shape".into())),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        match self {
            Literal::Array { payload, .. } => T::load(payload),
            Literal::Tuple(_) => Err(Error::ElementType("tuple literal has no flat data".into())),
        }
    }

    /// Decompose a tuple literal into its parts.
    pub fn to_tuple(&self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(parts) => Ok(parts.clone()),
            Literal::Array { .. } => Err(Error::Shape("literal is not a tuple".into())),
        }
    }
}

/// Parsed HLO module (stub: parsing requires the native runtime).
pub struct HloModuleProto;

impl HloModuleProto {
    pub fn from_text_file(_path: &str) -> Result<HloModuleProto> {
        Err(Error::Unavailable("HloModuleProto::from_text_file"))
    }
}

/// A computation handle (stub).
pub struct XlaComputation;

impl XlaComputation {
    pub fn from_proto(_proto: &HloModuleProto) -> XlaComputation {
        XlaComputation
    }
}

/// A device-resident buffer.  In this shim it simply owns a [`Literal`].
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    literal: Literal,
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.literal.clone())
    }
}

/// Argument types accepted by [`PjRtLoadedExecutable::execute_b`].
pub trait BufferArgument {}

impl BufferArgument for PjRtBuffer {}
impl<'a> BufferArgument for &'a PjRtBuffer {}

/// A compiled executable (stub: execution requires the native runtime).
pub struct PjRtLoadedExecutable;

impl PjRtLoadedExecutable {
    pub fn execute_b<L: BufferArgument>(&self, _args: &[L]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::Unavailable("PjRtLoadedExecutable::execute_b"))
    }
}

/// A PJRT client.  Host-buffer upload works; compilation is stubbed.
pub struct PjRtClient;

impl PjRtClient {
    pub fn cpu() -> Result<PjRtClient> {
        Ok(PjRtClient)
    }

    pub fn compile(&self, _computation: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Err(Error::Unavailable("PjRtClient::compile"))
    }

    pub fn buffer_from_host_buffer<T: NativeType>(
        &self,
        data: &[T],
        dims: &[usize],
        _device: Option<usize>,
    ) -> Result<PjRtBuffer> {
        let dims_i64: Vec<i64> = dims.iter().map(|&d| d as i64).collect();
        Ok(PjRtBuffer { literal: Literal::vec1(data).reshape(&dims_i64)? })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_round_trips_f32() {
        let lit = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]).reshape(&[2, 2]).unwrap();
        let shape = lit.array_shape().unwrap();
        assert_eq!(shape.dims(), &[2, 2]);
        assert_eq!(shape.ty(), ElementType::F32);
        assert_eq!(lit.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(lit.to_vec::<i32>().is_err());
    }

    #[test]
    fn reshape_checks_element_count() {
        let lit = Literal::vec1(&[1i32, 2, 3]);
        assert!(lit.reshape(&[2, 2]).is_err());
        assert!(lit.reshape(&[3, 1]).is_ok());
        // Negative dims are rejected even when their product matches.
        let lit4 = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert!(lit4.reshape(&[-2, -2]).is_err());
    }

    #[test]
    fn tuple_decomposition() {
        let t = Literal::Tuple(vec![Literal::vec1(&[1.0f32]), Literal::vec1(&[2i32])]);
        let parts = t.to_tuple().unwrap();
        assert_eq!(parts.len(), 2);
        assert!(Literal::vec1(&[1i32]).to_tuple().is_err());
        assert!(t.array_shape().is_err());
    }

    #[test]
    fn execution_paths_are_stubbed() {
        assert!(HloModuleProto::from_text_file("x.hlo.txt").is_err());
        let client = PjRtClient::cpu().unwrap();
        assert!(client.compile(&XlaComputation).is_err());
        let buf = client
            .buffer_from_host_buffer::<f32>(&[1.0, 2.0], &[2], None)
            .unwrap();
        assert_eq!(buf.to_literal_sync().unwrap().to_vec::<f32>().unwrap(), vec![1.0, 2.0]);
        assert!(PjRtLoadedExecutable.execute_b::<PjRtBuffer>(&[]).is_err());
    }
}
