//! Offline-build substrates: the small libraries this crate would normally
//! pull from crates.io (serde_json, criterion, proptest) implemented
//! in-crate, since only the xla closure is available in the baked registry.

pub mod bench;
pub mod json;
pub mod prop;

pub use json::Json;
