//! Scale benches: planner time vs cluster size, heap-simulator throughput
//! vs the retained greedy-rescan reference, beam/anneal bottleneck
//! quality vs the exhaustive optimum, the incremental anneal evaluator vs
//! the retained full-bisection reference at U up to 4096, and the
//! fork-join planner across a `threads` dimension (parity with the
//! sequential run gated at every row).  Results are written to
//! `BENCH_scale.json` (CI uploads it as an artifact) so the perf
//! trajectory accumulates across PRs.
//!
//! The `incremental` rows double as a differential test at scales the
//! unit batteries cannot afford: both evaluator paths must produce
//! bit-identical plans and accepted-move trajectories, and in smoke mode
//! the U = 256 evaluator-call counts are gated against committed caps —
//! counts are seed-deterministic, so the gate catches an accidental
//! return to one-bisection-per-move without any flaky wall-clock
//! threshold.
//!
//! Run: `cargo bench --bench scale` — or `cargo bench --bench scale --
//! --smoke` (also honored via `RINGADA_BENCH_SMOKE=1`) for the quick CI
//! profile: smaller sweeps, fewer samples, same JSON schema.

use ringada::config::{ClusterConfig, TrainingConfig};
use ringada::coordinator::{Coordinator, Planner, PlannerCosts, SearchParams};
use ringada::model::manifest::ModelHyper;
use ringada::model::ModelMeta;
use ringada::pipeline::{ScheduleBuilder, WireSizes};
use ringada::sim::{CostLut, Simulator};
use ringada::util::bench::{black_box, Bencher};
use ringada::util::json::Json;

fn meta(layers: usize) -> ModelMeta {
    ModelMeta::from_hyper(ModelHyper {
        name: "scale".into(),
        vocab: 2048,
        hidden: 64,
        layers,
        heads: 4,
        ffn: 256,
        bottleneck: 16,
        seq: 32,
        batch: 4,
        init_std: 0.02,
    })
}

fn costs(lut: &CostLut, m: &ModelMeta) -> PlannerCosts {
    PlannerCosts { block_fwd_s: lut.block_fwd_s, activation_bytes: m.activation_bytes() }
}

fn main() {
    let smoke = std::env::args().any(|a| a == "--smoke")
        || std::env::var("RINGADA_BENCH_SMOKE").map_or(false, |v| v == "1");
    let mut b = Bencher::coarse();
    println!("== scale benches ({}) ==", if smoke { "smoke" } else { "full" });

    // ---- planner time vs U (exhaustive where legal, beam/anneal beyond).
    let plan_sweep: &[usize] = if smoke { &[8, 16, 32] } else { &[8, 16, 32, 64, 128] };
    let params = if smoke { SearchParams::smoke() } else { SearchParams::default() };
    let mut planner_rows = Vec::new();
    for &u in plan_sweep {
        let m = meta(2 * u);
        let cl = ClusterConfig::synthetic(u, 11, 0.6).unwrap();
        let lut = CostLut::analytic(&m, 5.0);
        let planner = Planner::new(&m, &cl, costs(&lut, &m));
        let devices: Vec<usize> = (0..u).collect();
        let (mean_s, min_s) = {
            let r = b.bench(&format!("scale/plan_u{u}"), || {
                let plan = if u <= 8 {
                    planner.plan_exhaustive(&devices)
                } else {
                    planner.plan_beam_anneal_with(&devices, &params)
                };
                black_box(plan.unwrap());
            });
            (r.mean.as_secs_f64(), r.min.as_secs_f64())
        };
        planner_rows.push(Json::obj(vec![
            ("u", Json::num(u as f64)),
            ("layers", Json::num(2.0 * u as f64)),
            ("mean_s", Json::num(mean_s)),
            ("min_s", Json::num(min_s)),
        ]));
    }

    // ---- simulator throughput: heap dispatch vs the reference rescan.
    let sim_sweep: &[usize] = if smoke { &[16] } else { &[16, 64] };
    let steps = if smoke { 8 } else { 32 };
    let mut sim_rows = Vec::new();
    for &u in sim_sweep {
        let m = meta(2 * u);
        let cl = ClusterConfig::synthetic(u, 13, 0.5).unwrap();
        let lut = CostLut::analytic(&m, 5.0);
        let planner = Planner::new(&m, &cl, costs(&lut, &m));
        let devices: Vec<usize> = (0..u).collect();
        let plan = planner
            .plan_beam_anneal_with(&devices, &params)
            .expect("synthetic cluster must be plannable");
        let tr = TrainingConfig {
            rounds: 1,
            local_iters: 1,
            unfreeze_interval: 1,
            initial_depth: 1,
            ..Default::default()
        };
        let c = Coordinator::with_assignment(plan.assignment.clone(), &m, &cl, &tr).unwrap();
        let rp = c.round_plan(0).unwrap();
        let sizes = WireSizes { activation_bytes: m.activation_bytes(), head_bytes: 64 };
        let mut builder = ScheduleBuilder::new(plan.assignment, sizes, u);
        for s in 0..steps {
            builder.ringada_step(&rp, rp.initiators[s % u]).unwrap();
        }
        let (tasks, _) = builder.into_tasks();
        let n_tasks = tasks.len();
        let heap_mean = {
            let r = b.bench(&format!("scale/sim_heap_u{u}_{n_tasks}tasks"), || {
                let mut sim = Simulator::new(cl.clone(), lut.clone());
                black_box(sim.run(&tasks).unwrap());
            });
            r.mean.as_secs_f64()
        };
        let ref_mean = {
            let r = b.bench(&format!("scale/sim_reference_u{u}_{n_tasks}tasks"), || {
                let mut sim = Simulator::new(cl.clone(), lut.clone());
                black_box(sim.run_reference(&tasks).unwrap());
            });
            r.mean.as_secs_f64()
        };
        println!(
            "  -> u={u}: {n_tasks} tasks, heap {:.0} tasks/s, {:.2}x vs reference scan",
            n_tasks as f64 / heap_mean.max(1e-12),
            ref_mean / heap_mean.max(1e-12)
        );
        sim_rows.push(Json::obj(vec![
            ("u", Json::num(u as f64)),
            ("tasks", Json::num(n_tasks as f64)),
            ("heap_mean_s", Json::num(heap_mean)),
            ("reference_mean_s", Json::num(ref_mean)),
            (
                "heap_tasks_per_s",
                Json::num(n_tasks as f64 / heap_mean.max(1e-12)),
            ),
            (
                "speedup_vs_reference",
                Json::num(ref_mean / heap_mean.max(1e-12)),
            ),
        ]));
    }

    // ---- bottleneck quality: beam/anneal vs exhaustive on enumerable U.
    let q_sweep: &[usize] = if smoke { &[4, 6] } else { &[4, 6, 8] };
    let q_seeds = if smoke { 3u64 } else { 8 };
    let mut quality_rows = Vec::new();
    for &u in q_sweep {
        let mut worst_ratio = 1.0f64;
        for s in 0..q_seeds {
            let m = meta(2 * u);
            let cl = ClusterConfig::synthetic(u, 100 + s, 0.7).unwrap();
            let lut = CostLut::analytic(&m, 5.0);
            let planner = Planner::new(&m, &cl, costs(&lut, &m));
            let devices: Vec<usize> = (0..u).collect();
            let ex = planner.plan_exhaustive(&devices).unwrap();
            let ba = planner.plan_beam_anneal_with(&devices, &params).unwrap();
            worst_ratio = worst_ratio.max(ba.bottleneck_s / ex.bottleneck_s);
        }
        println!("  -> u={u}: worst beam/exhaustive bottleneck ratio {worst_ratio:.6}");
        quality_rows.push(Json::obj(vec![
            ("u", Json::num(u as f64)),
            ("seeds", Json::num(q_seeds as f64)),
            ("worst_ratio", Json::num(worst_ratio)),
        ]));
    }

    // ---- incremental anneal evaluator vs the retained full reference.
    // Single timed runs per path (counts are deterministic; the plans are
    // asserted bit-identical, which is the differential property the
    // parity battery pins at small U).
    //
    // CI gate (smoke, U = 256, `SearchParams::smoke`): a pruning
    // regression makes every proposal pay a full bisection, i.e.
    // `full_evals == anneal_moves == 400`.  The cap sits at 70% of that —
    // genuinely accepted (plateau) moves must pay full evaluations to
    // keep the trajectory bit-exact, so the cap leaves room for
    // accept-heavy landscapes while still failing the
    // one-bisection-per-move regression; the sweep-reduction floor
    // (total feasibility sweeps, reference / incremental) backs it up
    // from the other side.  Both counts are seed-deterministic.
    const U256_FULL_EVAL_CAP: usize = 280;
    const U256_MIN_SWEEP_REDUCTION: f64 = 1.25;
    let incr_sweep: &[usize] = if smoke { &[64, 256] } else { &[64, 256, 1024, 4096] };
    let mut incr_rows = Vec::new();
    for &u in incr_sweep {
        let m = meta(2 * u);
        let cl = ClusterConfig::synthetic(u, 17, 0.6).unwrap();
        let lut = CostLut::analytic(&m, 5.0);
        let planner = Planner::new(&m, &cl, costs(&lut, &m));
        let devices: Vec<usize> = (0..u).collect();
        let p_inc = SearchParams { incremental: true, ..params };
        let p_ref = SearchParams { incremental: false, ..params };
        let t0 = std::time::Instant::now();
        let (plan_inc, st_inc) = planner
            .plan_beam_anneal_traced(&devices, &p_inc)
            .expect("synthetic cluster must be plannable");
        let incr_s = t0.elapsed().as_secs_f64();
        let t0 = std::time::Instant::now();
        let (plan_ref, st_ref) = planner
            .plan_beam_anneal_traced(&devices, &p_ref)
            .expect("synthetic cluster must be plannable");
        let full_s = t0.elapsed().as_secs_f64();
        assert_eq!(
            plan_inc.assignment, plan_ref.assignment,
            "u={u}: incremental plan diverged from the full evaluator"
        );
        assert_eq!(plan_inc.bottleneck_s.to_bits(), plan_ref.bottleneck_s.to_bits());
        assert_eq!(
            st_inc.accepted, st_ref.accepted,
            "u={u}: accepted-move trajectories diverged"
        );
        let sweep_reduction = st_ref.anneal_sweeps as f64 / st_inc.anneal_sweeps.max(1) as f64;
        println!(
            "  -> u={u}: {} moves, {} full evals ({} pruned), sweeps {} vs {} \
             ({sweep_reduction:.1}x fewer), plan {:.3}s vs {:.3}s",
            st_inc.anneal_moves,
            st_inc.full_evals,
            st_inc.pruned_moves,
            st_inc.anneal_sweeps,
            st_ref.anneal_sweeps,
            incr_s,
            full_s,
        );
        incr_rows.push(Json::obj(vec![
            ("u", Json::num(u as f64)),
            ("layers", Json::num(2.0 * u as f64)),
            ("anneal_moves", Json::num(st_inc.anneal_moves as f64)),
            ("full_evals", Json::num(st_inc.full_evals as f64)),
            ("pruned_moves", Json::num(st_inc.pruned_moves as f64)),
            ("anneal_sweeps", Json::num(st_inc.anneal_sweeps as f64)),
            (
                "anneal_sweeps_reference",
                Json::num(st_ref.anneal_sweeps as f64),
            ),
            (
                "full_evals_reference",
                Json::num(st_ref.full_evals as f64),
            ),
            ("sweep_reduction", Json::num(sweep_reduction)),
            ("plan_s", Json::num(incr_s)),
            ("plan_s_reference", Json::num(full_s)),
            ("bottleneck_s", Json::num(plan_inc.bottleneck_s)),
        ]));
        if smoke && u == 256 {
            assert!(
                st_inc.full_evals <= U256_FULL_EVAL_CAP,
                "perf smoke gate: {} full evaluator calls at u=256 exceeds the \
                 committed cap {U256_FULL_EVAL_CAP} — the incremental pruning \
                 path has regressed toward one bisection per move",
                st_inc.full_evals,
            );
            assert!(
                sweep_reduction >= U256_MIN_SWEEP_REDUCTION,
                "perf smoke gate: sweep reduction {sweep_reduction:.2}x at u=256 \
                 below the committed floor {U256_MIN_SWEEP_REDUCTION}x",
            );
        }
    }

    // ---- threads dimension: the fork-join planner at 1/2/4/8 workers.
    // Parity is the gate at every row — plan bytes, accepted-move
    // trajectory, and evaluator-call counts must all match the threads=1
    // run exactly (counts are thread-count independent by construction,
    // so the speedup gate needs no wall-clock threshold; timings are
    // informational).
    let t_u = if smoke { 64 } else { 256 };
    let mut thread_rows = Vec::new();
    {
        let m = meta(2 * t_u);
        let cl = ClusterConfig::synthetic(t_u, 23, 0.6).unwrap();
        let lut = CostLut::analytic(&m, 5.0);
        let planner = Planner::new(&m, &cl, costs(&lut, &m));
        let devices: Vec<usize> = (0..t_u).collect();
        let mut baseline = None;
        for threads in [1usize, 2, 4, 8] {
            let p = SearchParams { restarts: 4, threads, ..params };
            let t0 = std::time::Instant::now();
            let (plan, st) = planner
                .plan_beam_anneal_traced(&devices, &p)
                .expect("synthetic cluster must be plannable");
            let wall_s = t0.elapsed().as_secs_f64();
            match &baseline {
                None => baseline = Some((plan.clone(), st.clone())),
                Some((bp, bs)) => {
                    assert_eq!(
                        plan.assignment,
                        bp.assignment,
                        "threads={threads} changed the plan"
                    );
                    assert_eq!(plan.bottleneck_s.to_bits(), bp.bottleneck_s.to_bits());
                    assert_eq!(
                        st.accepted,
                        bs.accepted,
                        "threads={threads} changed the accepted-move trajectory"
                    );
                    assert_eq!(
                        (st.anneal_moves, st.full_evals, st.pruned_moves, st.anneal_sweeps),
                        (bs.anneal_moves, bs.full_evals, bs.pruned_moves, bs.anneal_sweeps),
                        "threads={threads} changed the evaluator-call counts"
                    );
                }
            }
            println!(
                "  -> threads={threads}: u={t_u}, 4 restarts, {} full evals, plan {wall_s:.3}s \
                 (parity vs threads=1 asserted)",
                st.full_evals,
            );
            thread_rows.push(Json::obj(vec![
                ("threads", Json::num(threads as f64)),
                ("u", Json::num(t_u as f64)),
                ("restarts", Json::num(4.0)),
                ("plan_s", Json::num(wall_s)),
                ("full_evals", Json::num(st.full_evals as f64)),
                ("anneal_moves", Json::num(st.anneal_moves as f64)),
            ]));
        }
    }

    let out = Json::obj(vec![
        ("bench", Json::str("scale")),
        ("smoke", Json::Bool(smoke)),
        ("planner", Json::Arr(planner_rows)),
        ("sim", Json::Arr(sim_rows)),
        ("quality", Json::Arr(quality_rows)),
        ("incremental", Json::Arr(incr_rows)),
        ("threads", Json::Arr(thread_rows)),
    ]);
    std::fs::write("BENCH_scale.json", out.pretty()).expect("write BENCH_scale.json");
    println!("wrote BENCH_scale.json");
}
