"""Fused LayerNorm Pallas kernel (L1).

One VMEM pass per row tile computes mean, variance, normalization and the
affine transform — avoiding the two-kernel mean/var + normalize split common
in CUDA implementations (DESIGN.md §8).  The backward kernel uses the
standard closed-form LayerNorm gradient and accumulates the affine-parameter
gradients across grid steps in revisited output blocks.

Exposed as :func:`layernorm`, a ``jax.custom_vjp`` differentiable w.r.t.
``(x, gamma, beta)``.
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import as_rows, cdiv, pad_rows, pick_row_tile

EPS = 1e-5


def _fwd_kernel(x_ref, g_ref, b_ref, o_ref):
    x = x_ref[...]
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + EPS)
    o_ref[...] = xhat * g_ref[...][None, :] + b_ref[...][None, :]


def _bwd_kernel(x_ref, g_ref, gy_ref, gx_ref, gg_ref, gb_ref):
    step = pl.program_id(0)
    x = x_ref[...]
    g = g_ref[...]
    gy = gy_ref[...]

    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    rstd = jax.lax.rsqrt(var + EPS)
    xhat = (x - mean) * rstd

    gxhat = gy * g[None, :]
    m1 = jnp.mean(gxhat, axis=-1, keepdims=True)
    m2 = jnp.mean(gxhat * xhat, axis=-1, keepdims=True)
    gx_ref[...] = rstd * (gxhat - m1 - xhat * m2)

    @pl.when(step == 0)
    def _init():
        gg_ref[...] = jnp.zeros_like(gg_ref)
        gb_ref[...] = jnp.zeros_like(gb_ref)

    gg_ref[...] += jnp.sum(gy * xhat, axis=0)
    gb_ref[...] += jnp.sum(gy, axis=0)


def _ln_fwd_rows(x, g, b):
    rows_total, hidden = x.shape
    tile = pick_row_tile(rows_total)
    x_p, rows = pad_rows(x, tile)
    grid = (cdiv(x_p.shape[0], tile),)
    out = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
            pl.BlockSpec(g.shape, lambda i: (0,)),
            pl.BlockSpec(b.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x_p.shape, x.dtype),
        interpret=True,
    )(x_p, g, b)
    return out[:rows]


def _ln_bwd_rows(x, g, gy):
    rows_total, hidden = x.shape
    tile = pick_row_tile(rows_total)
    x_p, rows = pad_rows(x, tile)
    gy_p, _ = pad_rows(gy, tile)
    grid = (cdiv(x_p.shape[0], tile),)
    gx, gg, gb = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
            pl.BlockSpec(g.shape, lambda i: (0,)),
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
            pl.BlockSpec(g.shape, lambda i: (0,)),
            pl.BlockSpec(g.shape, lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x_p.shape, x.dtype),
            jax.ShapeDtypeStruct(g.shape, x.dtype),
            jax.ShapeDtypeStruct(g.shape, x.dtype),
        ],
        interpret=True,
    )(x_p, g, gy_p)
    return gx[:rows], gg, gb


@jax.custom_vjp
def layernorm(x, gamma, beta):
    """LayerNorm over the last axis with affine parameters.

    ``x: [..., H]``, ``gamma: [H]``, ``beta: [H]``.
    """
    rows, shape = as_rows(x)
    return _ln_fwd_rows(rows, gamma, beta).reshape(shape)


def _vjp_fwd(x, gamma, beta):
    return layernorm(x, gamma, beta), (x, gamma)


def _vjp_bwd(res, gy):
    x, gamma = res
    rows_x, shape = as_rows(x)
    rows_gy, _ = as_rows(gy)
    gx, gg, gb = _ln_bwd_rows(rows_x, gamma, rows_gy)
    return gx.reshape(shape), gg, gb


layernorm.defvjp(_vjp_fwd, _vjp_bwd)
