//! PJRT engine: loads AOT artifacts (HLO text) and executes them.
//!
//! One `Engine` = one PJRT CPU client + the compiled executables of one
//! artifact directory.  `PjRtClient` is `Rc`-based (not `Send`), so each
//! simulated edge device owns its own `Engine` on its own thread — which
//! also mirrors the deployment reality (one NPU runtime per device).
//!
//! Interchange is HLO *text* (`HloModuleProto::from_text_file`): jax ≥ 0.5
//! serialized protos use 64-bit instruction ids that xla_extension 0.5.1
//! rejects; the text parser reassigns ids (see DESIGN.md §3 and
//! /opt/xla-example/README.md).

use std::cell::RefCell;
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use xla::{HloModuleProto, PjRtClient, PjRtLoadedExecutable, XlaComputation};

use crate::error::{Error, Result};
use crate::model::manifest::Manifest;
use crate::runtime::tensor::HostTensor;

/// Cumulative execution statistics (profiling + the simulator's LUT source).
#[derive(Debug, Clone, Default)]
pub struct ExecStats {
    /// Per-executable: (invocations, total seconds).
    pub per_exe: BTreeMap<String, (u64, f64)>,
}

impl ExecStats {
    fn record(&mut self, name: &str, secs: f64) {
        let e = self.per_exe.entry(name.to_string()).or_insert((0, 0.0));
        e.0 += 1;
        e.1 += secs;
    }

    /// Mean seconds per invocation of `name`, if it ever ran.
    pub fn mean_secs(&self, name: &str) -> Option<f64> {
        self.per_exe.get(name).map(|(n, t)| t / (*n as f64).max(1.0))
    }

    pub fn total_invocations(&self) -> u64 {
        self.per_exe.values().map(|(n, _)| n).sum()
    }
}

/// A compiled artifact set, ready to execute.
pub struct Engine {
    client: PjRtClient,
    manifest: Manifest,
    dir: PathBuf,
    exes: BTreeMap<String, PjRtLoadedExecutable>,
    stats: RefCell<ExecStats>,
    /// When true, `execute` validates every argument against the manifest
    /// spec (cheap; disable only in the measured hot loop).
    pub check_args: bool,
}

impl Engine {
    /// Load `manifest.json` from `dir` and compile every executable.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir)?;
        let client = PjRtClient::cpu()?;
        let mut exes = BTreeMap::new();
        for (name, spec) in &manifest.executables {
            let path = dir.join(&spec.file);
            let proto = HloModuleProto::from_text_file(path.to_str().ok_or_else(
                || Error::other("non-utf8 artifact path"),
            )?)?;
            let comp = XlaComputation::from_proto(&proto);
            let exe = client.compile(&comp)?;
            exes.insert(name.clone(), exe);
        }
        Ok(Engine {
            client,
            manifest,
            dir,
            exes,
            stats: RefCell::new(ExecStats::default()),
            check_args: true,
        })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn artifact_dir(&self) -> &Path {
        &self.dir
    }

    pub fn client(&self) -> &PjRtClient {
        &self.client
    }

    pub fn stats(&self) -> ExecStats {
        self.stats.borrow().clone()
    }

    pub fn reset_stats(&self) {
        *self.stats.borrow_mut() = ExecStats::default();
    }

    /// Execute `name` with host tensors; returns the result tensors.
    ///
    /// aot.py lowers with `return_tuple=True`, so the PJRT output is a
    /// single tuple buffer which we decompose into the manifest's declared
    /// results.
    pub fn execute(&self, name: &str, args: &[HostTensor]) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.executable(name)?;
        if args.len() != spec.args.len() {
            return Err(Error::other(format!(
                "{name}: expected {} args, got {}",
                spec.args.len(),
                args.len()
            )));
        }
        if self.check_args {
            for (a, s) in args.iter().zip(&spec.args) {
                a.check_spec(s)?;
            }
        }
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::UnknownExecutable(name.to_string()))?;

        // Wall-clock here profiles real PJRT execution for the LUT; it
        // never feeds simulated time.
        let start = Instant::now(); // lint: allow(ambient-entropy, PJRT profiling timer)
        // Upload args as explicitly-owned device buffers and run through
        // `execute_b`.  (The Literal-based `execute` path leaks its
        // device-side input copies — ~250 KB/call measured — and is also
        // slower: one extra host copy per argument.)
        let buffers: Vec<xla::PjRtBuffer> =
            args.iter().map(|a| self.to_device(a)).collect::<Result<_>>()?;
        let result = exe.execute_b::<xla::PjRtBuffer>(&buffers)?;
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::other(format!("{name}: empty execution result")))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        let secs = start.elapsed().as_secs_f64();
        self.stats.borrow_mut().record(name, secs);

        if parts.len() != spec.results.len() {
            return Err(Error::other(format!(
                "{name}: manifest declares {} results, runtime produced {}",
                spec.results.len(),
                parts.len()
            )));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }

    /// Upload a host tensor to a device buffer (explicitly owned; freed on
    /// drop).  Public so callers can pin long-lived operands — e.g. block
    /// weights — device-side across many `execute_buffers` calls.
    pub fn to_device(&self, t: &HostTensor) -> Result<xla::PjRtBuffer> {
        use crate::runtime::tensor::TensorData;
        let buf = match &t.data {
            TensorData::F32(v) => {
                self.client.buffer_from_host_buffer::<f32>(v, &t.shape, None)?
            }
            TensorData::I32(v) => {
                self.client.buffer_from_host_buffer::<i32>(v, &t.shape, None)?
            }
        };
        Ok(buf)
    }

    /// Execute with caller-managed device buffers (the zero-copy hot path:
    /// weights stay resident, only activations move).
    pub fn execute_buffers(
        &self,
        name: &str,
        args: &[&xla::PjRtBuffer],
    ) -> Result<Vec<HostTensor>> {
        let spec = self.manifest.executable(name)?;
        if args.len() != spec.args.len() {
            return Err(Error::other(format!(
                "{name}: expected {} args, got {}",
                spec.args.len(),
                args.len()
            )));
        }
        let exe = self
            .exes
            .get(name)
            .ok_or_else(|| Error::UnknownExecutable(name.to_string()))?;
        let start = Instant::now(); // lint: allow(ambient-entropy, PJRT profiling timer)
        let result = exe.execute_b::<&xla::PjRtBuffer>(args)?;
        let tuple = result
            .first()
            .and_then(|r| r.first())
            .ok_or_else(|| Error::other(format!("{name}: empty execution result")))?
            .to_literal_sync()?;
        let parts = tuple.to_tuple()?;
        self.stats
            .borrow_mut()
            .record(name, start.elapsed().as_secs_f64());
        if parts.len() != spec.results.len() {
            return Err(Error::other(format!(
                "{name}: manifest declares {} results, runtime produced {}",
                spec.results.len(),
                parts.len()
            )));
        }
        parts.iter().map(HostTensor::from_literal).collect()
    }
}

impl std::fmt::Debug for Engine {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        f.debug_struct("Engine")
            .field("dir", &self.dir)
            .field("executables", &self.exes.keys().collect::<Vec<_>>())
            .finish()
    }
}
