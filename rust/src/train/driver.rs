//! The scheme drivers: real numerics + simulated clock (see mod docs).
//!
//! Structure of a run:
//! 1. numerics round-by-round on the real PJRT engine (loss per round);
//! 2. in parallel, the step schedule is appended to one global task DAG;
//! 3. after the last round, the DAG is simulated once and each round's
//!    completion time back-fills the loss curve's time axis.

use std::collections::{BTreeMap, VecDeque};

use crate::config::{ClusterConfig, ExperimentConfig, Scheme, TrainingConfig};
use crate::coordinator::{Coordinator, LayerAssignment, Planner, PlannerCosts};
use crate::data::{QaConfig, SyntheticQa};
use crate::error::{Error, Result};
use crate::metrics::{LossCurve, SpanMetrics};
use crate::model::{MemoryModel, ModelMeta};
use crate::pipeline::{ScheduleBuilder, WireSizes};
use crate::runtime::{Adam, DeviceWeights, Engine, HostTensor, ModelWeights, Rng, StageRunner};
use crate::sim::{CostLut, Scenario, ScenarioRun, Simulator};

/// Extra knobs the benches/examples tweak beyond [`ExperimentConfig`].
#[derive(Debug, Clone)]
pub struct TrainOptions {
    /// Evaluate F1/EM on the held-out set after training.
    pub eval: bool,
    /// Print a progress line per round.
    pub verbose: bool,
    /// Loss threshold defining "converged" for the Table-I columns.
    pub loss_threshold: f32,
}

impl Default for TrainOptions {
    fn default() -> Self {
        TrainOptions { eval: true, verbose: false, loss_threshold: 0.5 }
    }
}

/// Everything Table I and Fig. 3 need from one training run.
#[derive(Debug, Clone)]
pub struct TrainReport {
    pub scheme: Scheme,
    pub curve: LossCurve,
    /// Loss threshold used for the Table-I style convergence columns
    /// (comparable across schemes, unlike the plateau detector).
    pub loss_threshold: f32,
    /// Per-device average memory (MB) under the scheme's worst-case
    /// (full-depth) configuration — Table I column 1.
    pub memory_mb: f64,
    /// Round at which the plateau detector fired, if it did.
    pub converged_round: Option<usize>,
    /// Simulated wall-clock at the converged round (Table I column 3).
    pub converged_time_s: Option<f64>,
    /// Simulated time for the whole run.
    pub total_time_s: f64,
    /// Held-out span metrics (Table I columns 4-5); `None` if eval skipped.
    pub eval_metrics: Option<SpanMetrics>,
    /// Per-device compute utilization over the simulated run.
    pub utilization: Vec<f64>,
}

impl TrainReport {
    pub fn final_loss(&self) -> f32 {
        self.curve.final_loss().unwrap_or(f32::NAN)
    }

    /// Table I column 2: first epoch whose loss EMA crosses the threshold.
    pub fn epochs_to_convergence(&self) -> Option<f64> {
        self.curve.epochs_to_reach(self.loss_threshold)
    }

    /// Table I column 3: simulated time at that epoch.
    pub fn time_to_convergence(&self) -> Option<f64> {
        self.curve.time_to_reach(self.loss_threshold)
    }
}

/// Pending (delayed) update for PipeAdapter staleness modelling.
struct PendingUpdate {
    /// (block index, adapter grads).
    blocks: Vec<(usize, Vec<HostTensor>)>,
    head: Vec<HostTensor>,
}

/// Run `scheme` on the experiment; see module docs for semantics.
pub fn run_scheme(exp: &ExperimentConfig, scheme: Scheme) -> Result<TrainReport> {
    run_scheme_with(exp, scheme, &TrainOptions::default())
}

pub fn run_scheme_with(
    exp: &ExperimentConfig,
    scheme: Scheme,
    opts: &TrainOptions,
) -> Result<TrainReport> {
    exp.validate()?;
    let engine = Engine::load(&exp.artifact_dir)?;
    let manifest = engine.manifest().clone();
    let meta = ModelMeta::from_manifest(&manifest)?;
    let layers = meta.hyper.layers;
    let u = exp.cluster.len();

    // --- Data: one shard per device + a held-out eval set.
    let qa = QaConfig::for_model(meta.hyper.vocab, meta.hyper.seq);
    let shards: Vec<SyntheticQa> = (0..u)
        .map(|d| SyntheticQa::generate(&qa, d, exp.samples_per_device, exp.training.seed))
        .collect::<Result<_>>()?;
    let eval_set = SyntheticQa::generate(
        &qa,
        1_000_003, // out-of-band "device" id: held-out distribution mix
        exp.eval_samples,
        exp.training.seed ^ 0xE7A1,
    )?;

    // --- Weights + optimizers.
    let mut weights = ModelWeights::init(&manifest, exp.training.seed)?;
    let mut adapter_opts: Vec<Adam> = (0..layers)
        .map(|_| Adam::new(exp.training.lr, 4))
        .collect();
    let mut head_opt = Adam::new(exp.training.lr, weights.head.len());

    // --- Coordinator (planner costs from a quick profile of the engine).
    let lut = CostLut::from_engine(&engine, &weights, 2)?;
    let costs = PlannerCosts {
        block_fwd_s: lut.block_fwd_s,
        activation_bytes: meta.activation_bytes(),
    };
    let coordinator = Coordinator::initialize(&meta, &exp.cluster, &exp.training, costs)?;

    // --- One global schedule DAG for the whole run.
    let sizes = WireSizes {
        activation_bytes: meta.activation_bytes(),
        head_bytes: (meta.head_params * 4).max(4),
    };
    let mut builder = ScheduleBuilder::new(coordinator.assignment.clone(), sizes, u.max(2));

    let runner = StageRunner::new(&engine);
    // Pin every parameter tensor device-side; per step only activations and
    // the freshly-updated adapter/head tensors cross the host boundary
    // (EXPERIMENTS.md §Perf: 2.4x step time on `small`).
    let mut dev_weights = DeviceWeights::upload(&engine, &weights)?;
    let mut data_rng = Rng::new(exp.training.seed ^ 0xBA7C4);
    let mut round_losses: Vec<f32> = Vec::with_capacity(exp.training.rounds);
    let mut tracker = coordinator.tracker.clone();
    let mut converged_round = None;

    // PipeAdapter staleness queue: PipeDream-style weight stashing bounds
    // per-stage staleness to one version, so updates land one step late.
    // (A deeper delay diverges under Adam — and overstates the paper's
    // staleness; see DESIGN.md §2.)
    let staleness = if scheme == Scheme::PipeAdapter { 1 } else { 0 };
    let mut pending: VecDeque<PendingUpdate> = VecDeque::new();

    for round in 0..exp.training.rounds {
        let rp = coordinator.round_plan(round)?;
        let terminator = match scheme {
            Scheme::RingAda => rp.terminator_block,
            _ => 0,
        };
        let mut round_loss = 0.0f32;
        let mut losses_in_round = 0usize;

        // Single is the *centralized* baseline: same number of mini-batches
        // per round (epochs stay comparable across schemes, as in Fig. 3),
        // all on device 0.
        let initiators: Vec<usize> = match scheme {
            Scheme::Single => vec![0; u],
            _ => rp.initiators.clone(),
        };
        for (turn, &initiator) in initiators.iter().enumerate() {
            for _ in 0..exp.training.local_iters {
                // ---- Numerics.
                let batch = match scheme {
                    // Centralized baseline: draws from the union of shards.
                    Scheme::Single => {
                        let shard = &shards[data_rng.next_below(u)];
                        shard.sample_batch(meta.hyper.batch, &mut data_rng)?
                    }
                    _ => shards[initiator].sample_batch(meta.hyper.batch, &mut data_rng)?,
                };

                // Forward, storing the block inputs backward will need.
                let mut h = runner.embed_dev(&dev_weights, &batch.ids)?;
                let mut stored: Vec<Option<HostTensor>> = vec![None; layers];
                for l in 0..layers {
                    if l >= terminator {
                        stored[l] = Some(h.clone());
                    }
                    h = runner.block_fwd_dev(&dev_weights, l, &h)?;
                }
                let hg =
                    runner.head_loss_grad_dev(&dev_weights, &h, &batch.starts, &batch.ends)?;
                round_loss += hg.loss;
                losses_in_round += 1;

                // Backward with early stop at `terminator` (paper §IV.2).
                let mut gy = hg.gh.clone();
                let mut block_grads: Vec<(usize, Vec<HostTensor>)> = Vec::new();
                for l in (terminator..layers).rev() {
                    let x = stored[l].as_ref().ok_or_else(|| {
                        Error::other("missing stored activation for backward")
                    })?;
                    let bg = runner.block_bwd_dev(&dev_weights, l, x, &gy)?;
                    block_grads.push((l, bg.adapter));
                    gy = bg.gx;
                }
                // Global-norm gradient clipping (standard transformer
                // fine-tuning hygiene; keeps the delayed-update baseline
                // stable too).
                let mut head_grads = hg.head;
                clip_global_norm(&mut block_grads, &mut head_grads, 1.0)?;

                // Apply updates (immediately, or after the staleness delay).
                pending.push_back(PendingUpdate { blocks: block_grads, head: head_grads });
                while pending.len() > staleness {
                    let upd = pending.pop_front().unwrap();
                    for (l, grads) in upd.blocks {
                        {
                            let adapters = weights.adapter_mut(l);
                            let mut refs: Vec<&mut HostTensor> = adapters.iter_mut().collect();
                            let grefs: Vec<&HostTensor> = grads.iter().collect();
                            adapter_opts[l].update(&mut refs, &grefs)?;
                        }
                        dev_weights.refresh_adapter(&engine, l, weights.adapter(l))?;
                    }
                    {
                        let mut refs: Vec<&mut HostTensor> = weights.head.iter_mut().collect();
                        let grefs: Vec<&HostTensor> = upd.head.iter().collect();
                        head_opt.update(&mut refs, &grefs)?;
                    }
                    dev_weights.refresh_head(&engine, &weights.head)?;
                }

                // ---- Schedule (timing only; simulated at the end).
                match scheme {
                    Scheme::RingAda => builder.ringada_step(&rp, initiator)?,
                    Scheme::PipeAdapter => builder.pipe_adapter_step(&rp, initiator)?,
                    Scheme::Single => builder.single_step(&rp, 0, layers)?,
                };
            }
            // Head hand-off to the next initiator (ring schemes only).
            if scheme != Scheme::Single && turn + 1 < initiators.len() {
                builder.head_handoff(initiator, initiators[turn + 1], round)?;
            }
        }

        let mean_loss = round_loss / losses_in_round.max(1) as f32;
        round_losses.push(mean_loss);
        if opts.verbose {
            println!(
                "[{}] round {round:>4}  depth {}  loss {mean_loss:.4}",
                scheme.name(),
                rp.depth
            );
        }
        if tracker.observe(round, mean_loss) && converged_round.is_none() {
            converged_round = Some(round);
        }
    }

    // ---- Simulate the whole run once; back-fill the time axis.  An
    // attached straggler/link scenario perturbs the clock; dropout scripts
    // need the chunked re-planning driver (`simulate_scenario`) because the
    // numerics path holds a single static assignment.
    let (tasks, _handles) = builder.into_tasks();
    let mut simulator = match &exp.scenario {
        Some(sc) => {
            if !sc.dropouts().is_empty() {
                return Err(Error::Config(
                    "dropout scenarios are timing-only: use train::simulate_scenario \
                     (the numerics driver supports straggler/link scenarios)"
                        .into(),
                ));
            }
            Simulator::with_scenario(exp.cluster.clone(), lut, sc)?
        }
        None => Simulator::new(exp.cluster.clone(), lut),
    };
    let sim_report = simulator.run(&tasks)?;
    // Completion time of round r = max finish over its tasks.
    let mut round_done = vec![0.0f64; exp.training.rounds];
    for t in &tasks {
        if t.round < round_done.len() {
            round_done[t.round] = round_done[t.round].max(sim_report.finish[t.id]);
        }
    }
    let mut curve = LossCurve::default();
    for (r, &loss) in round_losses.iter().enumerate() {
        curve.push(r as f64, loss, round_done[r]);
    }
    let converged_time_s = converged_round.map(|r| round_done[r]);

    // ---- Memory (worst case: full depth) — Table I column 1.
    let mm = MemoryModel::new(meta.clone());
    let assignment_counts = coordinator.assignment.counts();
    let in_flight = if scheme == Scheme::PipeAdapter { u } else { 1 };
    let memory_mb = match scheme {
        Scheme::Single => mm.table1_avg_mb(scheme, &[layers], &[layers], 1),
        _ => mm.table1_avg_mb(scheme, &assignment_counts, &assignment_counts, in_flight),
    };

    // ---- Final evaluation.
    let eval_metrics = if opts.eval {
        Some(evaluate(&runner, &weights, &eval_set, meta.hyper.batch)?)
    } else {
        None
    };

    Ok(TrainReport {
        scheme,
        loss_threshold: opts.loss_threshold,
        total_time_s: sim_report.makespan,
        memory_mb,
        converged_round,
        converged_time_s,
        eval_metrics,
        utilization: sim_report.utilization(),
        curve,
    })
}

/// Scale all gradients so their global L2 norm is at most `max_norm`.
fn clip_global_norm(
    blocks: &mut [(usize, Vec<HostTensor>)],
    head: &mut [HostTensor],
    max_norm: f32,
) -> Result<()> {
    let mut sq = 0.0f64;
    for (_, grads) in blocks.iter() {
        for g in grads {
            sq += g.as_f32()?.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
        }
    }
    for g in head.iter() {
        sq += g.as_f32()?.iter().map(|&x| (x as f64) * (x as f64)).sum::<f64>();
    }
    let norm = sq.sqrt() as f32;
    if norm > max_norm {
        let scale = max_norm / norm;
        for (_, grads) in blocks.iter_mut() {
            for g in grads {
                for x in g.as_f32_mut()? {
                    *x *= scale;
                }
            }
        }
        for g in head.iter_mut() {
            for x in g.as_f32_mut()? {
                *x *= scale;
            }
        }
    }
    Ok(())
}

// ====================================================================
// Scenario simulation: timing-only runs under fault injection, with
// ring re-planning on device dropout.  No artifacts / PJRT needed — the
// LUT is analytic or pre-profiled, so this path exercises the whole
// coordinator/planner/schedule/simulator stack on any machine.
// ====================================================================

/// Plan a ring over the surviving devices and rebuild the coordinator.
///
/// `Single` needs no planner: all blocks sit on the first survivor.
fn plan_over_survivors(
    scheme: Scheme,
    planner: &Planner<'_>,
    alive: &[usize],
    meta: &ModelMeta,
    cluster: &ClusterConfig,
    training: &TrainingConfig,
) -> Result<Coordinator> {
    if alive.is_empty() {
        return Err(Error::Plan("no surviving devices".into()));
    }
    let assignment = match scheme {
        Scheme::Single => LayerAssignment::from_counts_for_devices(
            vec![alive[0]],
            &[meta.hyper.layers],
            cluster.len(),
        )?,
        _ => planner.plan_for_devices(alive)?.assignment,
    };
    Coordinator::with_assignment_for_cluster(assignment, meta, cluster, training)
}

/// Run `scheme`'s schedule under a fault/heterogeneity [`Scenario`] and
/// return the aggregate [`ScenarioRun`].
///
/// Mechanics (one chunk per round — the coordinator's natural control
/// boundary):
///
/// 1. each round's steps are appended to the [`ScheduleBuilder`] and
///    drained as one DAG chunk into the persistent [`Simulator`], whose
///    resource clocks and scenario windows carry across chunks;
/// 2. after each chunk, dropout events whose time has passed are applied:
///    the device is marked fail-stopped (the fail-stop is *detected* at the
///    round boundary), the planner re-plans the layer assignment over the
///    survivors — original device ids preserved so clocks and `R_{u,u'}`
///    stay valid — and a fresh builder resumes from the last applied
///    adapter update (the chunk barrier keeps the pause rule's
///    one-weight-version guarantee exact; see
///    [`ScheduleBuilder::drain_chunk`]);
/// 3. start/finish vectors, per-device busy time and link-byte totals
///    accumulate into a deterministically-ordered report, so the same
///    (seed, scenario) pair reproduces byte-identical output.
///
/// The fleet scheduler mirrors this round-advance / boundary-detect /
/// re-plan protocol against a pool *subset* (RingAda only, clock released
/// at admission) in two places pinned byte-identical to each other:
/// `fleet::JobExec::step` (the round-granular serving path) and the
/// retained legacy `fleet::run_job` (`serve_reference`).  A semantic
/// change to dropout detection or re-planning here must be applied to
/// both, or fleet runs and single-job scenario runs will disagree on the
/// same script.
pub fn simulate_scenario(
    meta: &ModelMeta,
    cluster: &ClusterConfig,
    training: &TrainingConfig,
    scheme: Scheme,
    scenario: &Scenario,
    lut: &CostLut,
) -> Result<ScenarioRun> {
    cluster.validate()?;
    training.validate()?;
    scenario.validate(cluster.len())?;
    let layers = meta.hyper.layers;
    let sizes = WireSizes {
        activation_bytes: meta.activation_bytes(),
        head_bytes: (meta.head_params * 4).max(4),
    };
    let costs = PlannerCosts {
        block_fwd_s: lut.block_fwd_s,
        activation_bytes: meta.activation_bytes(),
    };
    let planner = Planner::new(meta, cluster, costs);

    let mut alive: Vec<usize> = (0..cluster.len()).collect();
    let mut pending_drops: VecDeque<(f64, usize)> = scenario.dropouts().into();
    let mut sim = Simulator::with_scenario(cluster.clone(), lut.clone(), scenario)?;

    let mut coordinator =
        plan_over_survivors(scheme, &planner, &alive, meta, cluster, training)?;
    let mut builder =
        ScheduleBuilder::new(coordinator.assignment.clone(), sizes, alive.len().max(2));

    let mut device_busy = vec![0.0; cluster.len()];
    let mut link_bytes: BTreeMap<(usize, usize), usize> = BTreeMap::new();
    let mut chunk_makespans = Vec::with_capacity(training.rounds);
    let mut chunk_windows = Vec::with_capacity(training.rounds);
    let mut chunk_utilizations = Vec::with_capacity(training.rounds);
    let mut chunk_task_counts = Vec::with_capacity(training.rounds);
    let mut starts = Vec::new();
    let mut finishes = Vec::new();
    let mut replans = 0usize;
    let mut dropped: Vec<usize> = Vec::new();

    for round in 0..training.rounds {
        let rp = coordinator.round_plan(round)?;
        // The per-round mini-batch budget stays fixed at the original
        // cluster size even after dropouts (the Fig. 3 comparability
        // convention): every round trains the same number of batches, so
        // scenario deltas measure *capacity* loss, not budget shrinkage.
        // Surviving initiators absorb the dead devices' turns.
        let turns = cluster.len();
        let initiators: Vec<usize> = match scheme {
            Scheme::Single => vec![alive[0]; turns],
            _ => (0..turns).map(|t| rp.initiators[t % rp.initiators.len()]).collect(),
        };
        for (turn, &initiator) in initiators.iter().enumerate() {
            for _ in 0..training.local_iters {
                match scheme {
                    Scheme::RingAda => builder.ringada_step(&rp, initiator)?,
                    Scheme::PipeAdapter => builder.pipe_adapter_step(&rp, initiator)?,
                    Scheme::Single => builder.single_step(&rp, alive[0], layers)?,
                };
            }
            let next = initiators.get(turn + 1).copied();
            if scheme != Scheme::Single {
                if let Some(next) = next.filter(|&n| n != initiator) {
                    builder.head_handoff(initiator, next, round)?;
                }
            }
        }

        let (tasks, _handles) = builder.drain_chunk();
        let report = sim.run(&tasks)?;
        for (d, b) in report.device_busy.iter().enumerate() {
            device_busy[d] += b;
        }
        for (&link, &bytes) in &report.link_bytes {
            *link_bytes.entry(link).or_insert(0) += bytes;
        }
        chunk_makespans.push(sim.now);
        chunk_task_counts.push(tasks.len());
        // Per-chunk utilization over this chunk's own window (release →
        // last finish) and the devices alive while it ran — dividing by the
        // global clock would under-report every chunk after the first.
        chunk_windows.push(report.window_s);
        let chunk_util = if report.window_s > 0.0 && !alive.is_empty() {
            alive.iter().map(|&d| report.device_busy[d]).sum::<f64>()
                / (report.window_s * alive.len() as f64)
        } else {
            0.0
        };
        chunk_utilizations.push(chunk_util);
        starts.extend_from_slice(&report.start);
        finishes.extend_from_slice(&report.finish);

        // Fail-stops detected at this round boundary.
        let mut need_replan = false;
        while pending_drops.front().map_or(false, |&(at, _)| at <= sim.now) {
            let (_, d) = pending_drops.pop_front().unwrap();
            sim.drop_device(d);
            alive.retain(|&x| x != d);
            dropped.push(d);
            need_replan = true;
        }
        if need_replan && round + 1 < training.rounds {
            if alive.is_empty() {
                return Err(Error::Plan(
                    "scenario dropped every device; nothing left to train on".into(),
                ));
            }
            replans += 1;
            coordinator =
                plan_over_survivors(scheme, &planner, &alive, meta, cluster, training)?;
            builder = ScheduleBuilder::new(
                coordinator.assignment.clone(),
                sizes,
                alive.len().max(2),
            );
        }
    }

    Ok(ScenarioRun {
        scheme,
        scenario: scenario.name.clone(),
        rounds: training.rounds,
        makespan_s: sim.now,
        device_busy,
        link_bytes,
        chunk_makespans,
        chunk_windows,
        chunk_utilizations,
        chunk_task_counts,
        starts,
        finishes,
        replans,
        dropped,
    })
}

/// F1/EM over a held-out set with greedy span decoding.
pub fn evaluate(
    runner: &StageRunner,
    weights: &ModelWeights,
    eval_set: &SyntheticQa,
    batch: usize,
) -> Result<SpanMetrics> {
    let mut metrics = SpanMetrics::default();
    for (b, real) in eval_set.eval_batches(batch)? {
        let h = runner.full_fwd(weights, &b.ids)?;
        let (ps, pe) = runner.head_predict(weights, &h)?;
        metrics.add_batch(&ps, &pe, b.starts.as_i32()?, b.ends.as_i32()?, real);
    }
    Ok(metrics)
}
