"""L2 — the RingAda model as per-stage JAX functions (build-time only).

The model is a BERT-style encoder with one *serial adapter* after each
block's FFN "add & layer-norm" sublayer (paper Fig. 1) and an extractive-QA
span head (start/end logits), standing in for mBERT + MAD-X adapters on
SQuAD (DESIGN.md §2).

The model is deliberately decomposed into the five stage functions below —
not one monolithic ``train_step`` — because RingAda's whole point is that
*different devices own different contiguous block ranges* and backprop
early-stops at the terminator block.  The Rust coordinator (L3) composes
these stages around the ring at run time:

* :func:`embed_fwd`       — run by the initiator on its local ``Emb`` copy.
* :func:`block_fwd`       — one transformer block + adapter; the SAME lowered
                            executable serves every block (weights are
                            arguments), so any partition composes.
* :func:`block_bwd`       — VJP of ``block_fwd`` w.r.t. the block input and
                            the ADAPTER parameters only (backbone frozen);
                            recompute-based, so no saved activations cross
                            the AOT boundary.
* :func:`head_fwd` / :func:`head_loss_grad` / :func:`head_predict`
                          — run by the initiator on its local ``Hed`` copy;
                            labels never leave the device.

``aot.py`` lowers each of these to HLO text for the Rust runtime.
"""

from __future__ import annotations

from dataclasses import dataclass, field

import jax
import jax.numpy as jnp

from .kernels import adapter, gelu, layernorm, mha


# ---------------------------------------------------------------------------
# Configuration
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ModelConfig:
    """Hyperparameters of one lowered artifact set.

    ``batch`` and ``seq`` are baked into the HLO shapes (PJRT executables are
    shape-specialized); the Rust side pads the final eval batch.
    """

    name: str
    vocab: int
    hidden: int
    layers: int
    heads: int
    ffn: int
    bottleneck: int
    seq: int
    batch: int
    init_std: float = 0.02

    @property
    def head_dim(self) -> int:
        return self.hidden // self.heads

    def __post_init__(self):
        assert self.hidden % self.heads == 0, "hidden must divide by heads"


#: Artifact configurations.  ``tiny`` drives the test suites, ``small`` the
#: criterion benches, ``e2e`` the end-to-end validation run (≈98 M params —
#: mBERT-class, matching the paper's model scale).
CONFIGS: dict[str, ModelConfig] = {
    cfg.name: cfg
    for cfg in [
        ModelConfig("tiny", vocab=512, hidden=64, layers=4, heads=4, ffn=256, bottleneck=16, seq=32, batch=4),
        ModelConfig("small", vocab=2048, hidden=256, layers=8, heads=8, ffn=1024, bottleneck=32, seq=64, batch=8),
        ModelConfig("e2e", vocab=8192, hidden=768, layers=12, heads=12, ffn=3072, bottleneck=64, seq=128, batch=8),
    ]
}


# ---------------------------------------------------------------------------
# Parameter inventory (shared with the Rust runtime via manifest.json)
# ---------------------------------------------------------------------------


@dataclass(frozen=True)
class ParamSpec:
    name: str
    shape: tuple[int, ...]
    init: str  # "normal" (std=init_std), "zeros", "ones"
    trainable: bool = False


def embed_param_specs(c: ModelConfig) -> list[ParamSpec]:
    return [
        ParamSpec("tok_emb", (c.vocab, c.hidden), "normal"),
        ParamSpec("pos_emb", (c.seq, c.hidden), "normal"),
        ParamSpec("emb_ln_g", (c.hidden,), "ones"),
        ParamSpec("emb_ln_b", (c.hidden,), "zeros"),
    ]


def block_param_specs(c: ModelConfig) -> list[ParamSpec]:
    """Per-block parameters, in the positional order ``block_fwd`` takes
    them.  The four trailing adapter tensors are the trainable ones."""
    h, f, m = c.hidden, c.ffn, c.bottleneck
    return [
        ParamSpec("wqkv", (h, 3 * h), "normal"),
        ParamSpec("bqkv", (3 * h,), "zeros"),
        ParamSpec("wo", (h, h), "normal"),
        ParamSpec("bo", (h,), "zeros"),
        ParamSpec("ln1_g", (h,), "ones"),
        ParamSpec("ln1_b", (h,), "zeros"),
        ParamSpec("w1", (h, f), "normal"),
        ParamSpec("b1", (f,), "zeros"),
        ParamSpec("w2", (f, h), "normal"),
        ParamSpec("b2", (h,), "zeros"),
        ParamSpec("ln2_g", (h,), "ones"),
        ParamSpec("ln2_b", (h,), "zeros"),
        # Adapter — W_up starts at zero so a freshly inserted adapter is an
        # exact identity (the residual path), the standard stabilizer.
        ParamSpec("a_wd", (h, m), "normal", trainable=True),
        ParamSpec("a_bd", (m,), "zeros", trainable=True),
        ParamSpec("a_wu", (m, h), "zeros", trainable=True),
        ParamSpec("a_bu", (h,), "zeros", trainable=True),
    ]


NUM_ADAPTER_PARAMS = 4  # a_wd, a_bd, a_wu, a_bu — the block's trainable tail


def head_param_specs(c: ModelConfig) -> list[ParamSpec]:
    return [
        ParamSpec("w_head", (c.hidden, 2), "normal", trainable=True),
        ParamSpec("b_head", (2,), "zeros", trainable=True),
    ]


# ---------------------------------------------------------------------------
# Stage functions
# ---------------------------------------------------------------------------


def embed_fwd(ids, tok_emb, pos_emb, ln_g, ln_b):
    """``ids: s32[B, S]`` → hidden states ``f32[B, S, H]``."""
    h = tok_emb[ids] + pos_emb[None, :, :]
    return layernorm(h, ln_g, ln_b)


def _block_apply(x, wqkv, bqkv, wo, bo, ln1_g, ln1_b, w1, b1, w2, b2,
                 ln2_g, ln2_b, a_wd, a_bd, a_wu, a_bu, *, heads: int):
    """One post-LN transformer block with a trailing serial adapter."""
    bsz, seq, hidden = x.shape
    hd = hidden // heads

    qkv = jnp.dot(x, wqkv) + bqkv  # [B, S, 3H]
    q, k, v = jnp.split(qkv, 3, axis=-1)

    def to_bh(t):  # [B, S, H] -> [B*heads, S, hd]
        return (
            t.reshape(bsz, seq, heads, hd)
            .transpose(0, 2, 1, 3)
            .reshape(bsz * heads, seq, hd)
        )

    def from_bh(t):
        return (
            t.reshape(bsz, heads, seq, hd)
            .transpose(0, 2, 1, 3)
            .reshape(bsz, seq, hidden)
        )

    attn = from_bh(mha(to_bh(q), to_bh(k), to_bh(v)))
    h1 = layernorm(x + jnp.dot(attn, wo) + bo, ln1_g, ln1_b)
    ff = jnp.dot(gelu(jnp.dot(h1, w1) + b1), w2) + b2
    h2 = layernorm(h1 + ff, ln2_g, ln2_b)
    return adapter(h2, a_wd, a_bd, a_wu, a_bu)


def make_block_fwd(c: ModelConfig):
    def block_fwd(x, *params):
        return _block_apply(x, *params, heads=c.heads)

    return block_fwd


def make_block_bwd(c: ModelConfig):
    """VJP of the block w.r.t. ``(x, adapter params)`` — the backbone is
    frozen, so its cotangents are never formed.  Activations are recomputed
    inside (nothing but ``x`` crosses the stage boundary), which is what
    keeps RingAda's per-device activation memory flat (DESIGN.md §6)."""
    n_backbone = len(block_param_specs(c)) - NUM_ADAPTER_PARAMS

    def block_bwd(x, *params_and_gy):
        params, gy = params_and_gy[:-1], params_and_gy[-1]
        backbone, adapters = params[:n_backbone], params[n_backbone:]

        def f(x, a_wd, a_bd, a_wu, a_bu):
            return _block_apply(x, *backbone, a_wd, a_bd, a_wu, a_bu, heads=c.heads)

        _, vjp = jax.vjp(f, x, *adapters)
        gx, g_wd, g_bd, g_wu, g_bu = vjp(gy)
        return gx, g_wd, g_bd, g_wu, g_bu

    return block_bwd


def head_fwd(h, w_head, b_head):
    """Span logits ``f32[B, S, 2]`` (start, end)."""
    return jnp.dot(h, w_head) + b_head


def _span_loss(h, w_head, b_head, starts, ends):
    logits = head_fwd(h, w_head, b_head)
    log_s = jax.nn.log_softmax(logits[..., 0], axis=-1)  # [B, S]
    log_e = jax.nn.log_softmax(logits[..., 1], axis=-1)
    bidx = jnp.arange(h.shape[0])
    nll = -(log_s[bidx, starts] + log_e[bidx, ends]) / 2.0
    return jnp.mean(nll)


def head_loss_grad(h, w_head, b_head, starts, ends):
    """Loss + gradients w.r.t. the hidden states and head parameters.

    Run by the initiator only — ``starts``/``ends`` (the labels) never
    leave the device that owns the mini-batch.
    """
    loss, vjp = jax.vjp(lambda h, w, b: _span_loss(h, w, b, starts, ends),
                        h, w_head, b_head)
    g_h, g_w, g_b = vjp(jnp.float32(1.0))
    return loss, g_h, g_w, g_b


def head_predict(h, w_head, b_head):
    """Greedy span decode: ``(starts s32[B], ends s32[B])``."""
    logits = head_fwd(h, w_head, b_head)
    starts = jnp.argmax(logits[..., 0], axis=-1).astype(jnp.int32)
    ends = jnp.argmax(logits[..., 1], axis=-1).astype(jnp.int32)
    return starts, ends


# ---------------------------------------------------------------------------
# Whole-model reference (used by python tests only, never lowered)
# ---------------------------------------------------------------------------


@dataclass
class ModelParams:
    """Host-side parameter container for the python-level tests."""

    embed: list
    blocks: list  # [layers][param]
    head: list
    config: ModelConfig = field(repr=False, default=None)


def init_params(c: ModelConfig, key) -> ModelParams:
    def init_one(spec: ParamSpec, k):
        if spec.init == "normal":
            return jax.random.normal(k, spec.shape) * c.init_std
        if spec.init == "ones":
            return jnp.ones(spec.shape)
        return jnp.zeros(spec.shape)

    keys = iter(jax.random.split(key, 4096))
    embed = [init_one(s, next(keys)) for s in embed_param_specs(c)]
    blocks = [
        [init_one(s, next(keys)) for s in block_param_specs(c)]
        for _ in range(c.layers)
    ]
    head = [init_one(s, next(keys)) for s in head_param_specs(c)]
    return ModelParams(embed, blocks, head, c)


def model_fwd(c: ModelConfig, params: ModelParams, ids):
    h = embed_fwd(ids, *params.embed)
    block = make_block_fwd(c)
    for bp in params.blocks:
        h = block(h, *bp)
    return h
