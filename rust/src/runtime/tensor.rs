//! Host-side tensor type bridging `manifest.json` specs and XLA literals.

use xla::Literal;

use crate::error::{Error, Result};
use crate::model::manifest::TensorSpec;

/// Element storage: this stack only traffics f32 and s32 (see aot.py).
#[derive(Debug, Clone, PartialEq)]
pub enum TensorData {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

/// A host tensor with shape; the unit of exchange with the PJRT engine and
/// between simulated devices.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: TensorData,
}

impl HostTensor {
    pub fn f32(shape: impl Into<Vec<usize>>, data: Vec<f32>) -> Result<Self> {
        let shape = shape.into();
        let numel: usize = shape.iter().product();
        if data.len() != numel {
            return Err(Error::ShapeMismatch {
                name: "f32 tensor".into(),
                expected: shape,
                got: vec![data.len()],
            });
        }
        Ok(HostTensor { shape, data: TensorData::F32(data) })
    }

    pub fn i32(shape: impl Into<Vec<usize>>, data: Vec<i32>) -> Result<Self> {
        let shape = shape.into();
        let numel: usize = shape.iter().product();
        if data.len() != numel {
            return Err(Error::ShapeMismatch {
                name: "i32 tensor".into(),
                expected: shape,
                got: vec![data.len()],
            });
        }
        Ok(HostTensor { shape, data: TensorData::I32(data) })
    }

    pub fn zeros_f32(shape: impl Into<Vec<usize>>) -> Self {
        let shape = shape.into();
        let numel: usize = shape.iter().product();
        HostTensor { shape, data: TensorData::F32(vec![0.0; numel]) }
    }

    pub fn numel(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn byte_size(&self) -> usize {
        self.numel() * 4
    }

    pub fn dtype_name(&self) -> &'static str {
        match self.data {
            TensorData::F32(_) => "f32",
            TensorData::I32(_) => "s32",
        }
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match &self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(Error::other("tensor is s32, expected f32")),
        }
    }

    pub fn as_f32_mut(&mut self) -> Result<&mut Vec<f32>> {
        match &mut self.data {
            TensorData::F32(v) => Ok(v),
            TensorData::I32(_) => Err(Error::other("tensor is s32, expected f32")),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match &self.data {
            TensorData::I32(v) => Ok(v),
            TensorData::F32(_) => Err(Error::other("tensor is f32, expected s32")),
        }
    }

    /// Scalar extraction (loss values etc.).
    pub fn scalar_f32(&self) -> Result<f32> {
        let v = self.as_f32()?;
        if v.len() != 1 {
            return Err(Error::other(format!(
                "expected scalar, got {:?}",
                self.shape
            )));
        }
        Ok(v[0])
    }

    /// Validate against a manifest tensor spec (shape + dtype).
    pub fn check_spec(&self, spec: &TensorSpec) -> Result<()> {
        if self.shape != spec.shape {
            return Err(Error::ShapeMismatch {
                name: spec.name.clone(),
                expected: spec.shape.clone(),
                got: self.shape.clone(),
            });
        }
        if self.dtype_name() != spec.dtype {
            return Err(Error::other(format!(
                "dtype mismatch for `{}`: manifest says {}, tensor is {}",
                spec.name, spec.dtype, self.dtype_name()
            )));
        }
        Ok(())
    }

    pub fn to_literal(&self) -> Result<Literal> {
        let dims: Vec<i64> = self.shape.iter().map(|&d| d as i64).collect();
        let lit = match &self.data {
            TensorData::F32(v) => Literal::vec1(v).reshape(&dims)?,
            TensorData::I32(v) => Literal::vec1(v).reshape(&dims)?,
        };
        Ok(lit)
    }

    pub fn from_literal(lit: &Literal) -> Result<Self> {
        let shape = lit.array_shape()?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        match shape.ty() {
            xla::ElementType::F32 => {
                HostTensor::f32(dims, lit.to_vec::<f32>()?)
            }
            xla::ElementType::S32 => {
                HostTensor::i32(dims, lit.to_vec::<i32>()?)
            }
            other => Err(Error::other(format!(
                "unsupported literal element type {other:?}"
            ))),
        }
    }

    /// Max absolute difference vs another f32 tensor (test helper).
    pub fn max_abs_diff(&self, other: &HostTensor) -> Result<f32> {
        let a = self.as_f32()?;
        let b = other.as_f32()?;
        if a.len() != b.len() {
            return Err(Error::other("length mismatch in max_abs_diff"));
        }
        Ok(a.iter().zip(b).map(|(x, y)| (x - y).abs()).fold(0.0, f32::max))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn f32_shape_checked() {
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 6]).is_ok());
        assert!(HostTensor::f32(vec![2, 3], vec![0.0; 5]).is_err());
    }

    #[test]
    fn scalar_extraction() {
        let t = HostTensor::f32(vec![], vec![7.5]).unwrap();
        assert_eq!(t.scalar_f32().unwrap(), 7.5);
        let t2 = HostTensor::f32(vec![2], vec![1.0, 2.0]).unwrap();
        assert!(t2.scalar_f32().is_err());
    }

    #[test]
    fn spec_check() {
        let spec = TensorSpec { name: "x".into(), shape: vec![2, 2], dtype: "f32".into() };
        let ok = HostTensor::zeros_f32(vec![2, 2]);
        ok.check_spec(&spec).unwrap();
        let bad_shape = HostTensor::zeros_f32(vec![4]);
        assert!(bad_shape.check_spec(&spec).is_err());
        let bad_dtype = HostTensor::i32(vec![2, 2], vec![0; 4]).unwrap();
        assert!(bad_dtype.check_spec(&spec).is_err());
    }

    #[test]
    fn literal_round_trip() {
        let t = HostTensor::f32(vec![2, 3], (0..6).map(|i| i as f32).collect()).unwrap();
        let lit = t.to_literal().unwrap();
        let back = HostTensor::from_literal(&lit).unwrap();
        assert_eq!(t, back);

        let ti = HostTensor::i32(vec![4], vec![1, -2, 3, -4]).unwrap();
        let back = HostTensor::from_literal(&ti.to_literal().unwrap()).unwrap();
        assert_eq!(ti, back);
    }

    #[test]
    fn max_abs_diff_works() {
        let a = HostTensor::f32(vec![3], vec![1.0, 2.0, 3.0]).unwrap();
        let b = HostTensor::f32(vec![3], vec![1.5, 2.0, 2.0]).unwrap();
        assert_eq!(a.max_abs_diff(&b).unwrap(), 1.0);
    }
}
