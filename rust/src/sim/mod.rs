//! Trace-based discrete-event simulator (the paper's §V evaluation
//! methodology): executes a schedule DAG under resource exclusivity —
//! one compute task at a time per device, one transfer at a time per
//! directed link — with durations from the profiled [`CostLut`] scaled by
//! each device's `C_u^comp` and link rates from `R_{u,u'}`.
//!
//! Scheduling policy: greedy list scheduling; among all ready tasks, start
//! the one with the earliest feasible start time (ties → lowest task id,
//! i.e. generation order).  Scheme *semantics* (pause rule, early stop,
//! in-flight bounds) live entirely in the DAG's dependencies — the
//! simulator never special-cases a scheme.
//!
//! ## Dispatch data structure (heap, O(T log T))
//!
//! [`Simulator::run`] keeps the ready set in a binary min-heap keyed by
//! `(feasible start, task id)` — the same total order the policy above
//! defines.  Keys go stale when a resource clock advances after the entry
//! was pushed, so dispatch re-keys lazily: pop the minimum, recompute its
//! true feasible start, and re-insert if the key was stale.  The invariants
//! that make this byte-identical to a full rescan of the ready list:
//!
//! * resource clocks and the release floor are monotone — a heap key can
//!   only *underestimate* a task's true feasible start, never overestimate;
//! * a task's `ready_time` (max dep finish) is final before it is pushed
//!   (all deps completed), so it never contributes staleness;
//! * each task has exactly one live heap entry (pop-then-reinsert), so a
//!   popped entry whose recomputed start equals its key is the true
//!   minimum of the current ready set under `(start, id)`.
//!
//! The greedy O(T·R) rescan is retained as
//! [`Simulator::run_reference`] — the executable specification the
//! differential tests compare against, byte for byte.

pub mod lut;
pub mod scenario;

pub use lut::CostLut;
pub use scenario::{Scenario, ScenarioEvent, ScenarioRun};

use std::cmp::Ordering;
use std::collections::{BTreeMap, BinaryHeap};
use std::sync::Arc;

use crate::config::ClusterConfig;
use crate::error::{Error, Result};
use crate::pipeline::{Kind, Resource, Task, TaskId};

/// Simulation output for one DAG chunk.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Finish time (s) per task id.
    pub finish: Vec<f64>,
    /// Start time (s) per task id.
    pub start: Vec<f64>,
    /// Makespan: the simulator's *global* clock after this chunk (absolute,
    /// includes every earlier chunk's time).
    pub makespan: f64,
    /// Clock at which this chunk was released (`Simulator::now` when `run`
    /// was called).
    pub release: f64,
    /// This chunk's own scheduling window, release → last finish (0 for an
    /// empty chunk).  Utilization denominators use this, not the global
    /// clock: dividing a later chunk's busy time by the absolute makespan
    /// under-reports every chunk after the first.
    pub window_s: f64,
    /// Per-device busy seconds (compute only) within this chunk.
    pub device_busy: Vec<f64>,
    /// Total bytes moved per directed link.  Ordered map: reports are
    /// iterated and serialized, so iteration order is part of the replay
    /// contract (lint rule `hash-collections`).
    pub link_bytes: BTreeMap<(usize, usize), usize>,
}

impl SimReport {
    /// Device utilization over this chunk's own window (release → last
    /// finish).  For a single-chunk simulation from t = 0 this equals the
    /// old busy/makespan ratio.
    pub fn utilization(&self) -> Vec<f64> {
        self.device_busy
            .iter()
            .map(|&b| if self.window_s > 0.0 { b / self.window_s } else { 0.0 })
            .collect()
    }

    /// Device utilization over the *global* clock — the pre-fix semantics,
    /// kept for consumers that want busy time amortized over the whole run.
    pub fn global_utilization(&self) -> Vec<f64> {
        self.device_busy
            .iter()
            .map(|&b| if self.makespan > 0.0 { b / self.makespan } else { 0.0 })
            .collect()
    }
}

/// Heap key for the ready queue: ascending `(feasible start, task id)` —
/// the same total order the greedy rescan uses, so dispatch decisions are
/// identical.  `Ord` is reversed because [`BinaryHeap`] is a max-heap.
#[derive(Debug, Clone, Copy, PartialEq)]
struct ReadyKey {
    start: f64,
    id: TaskId,
}

impl Eq for ReadyKey {}

impl Ord for ReadyKey {
    fn cmp(&self, other: &Self) -> Ordering {
        // Reversed: smallest (start, id) surfaces at the heap top.  Start
        // times are finite (validated cluster ⇒ finite durations), so
        // total_cmp agrees with the arithmetic order.
        other
            .start
            .total_cmp(&self.start)
            .then_with(|| other.id.cmp(&self.id))
    }
}

impl PartialOrd for ReadyKey {
    fn partial_cmp(&self, other: &Self) -> Option<Ordering> {
        Some(self.cmp(other))
    }
}

/// Reusable per-chunk dispatch scratch: the dependents adjacency, indegree
/// and ready-time tables, and the ready heap were rebuilt (allocated) on
/// every [`Simulator::run`] call — for fleet runs that is thousands of
/// chunks against one simulator, all allocator traffic.  The buffers are
/// fully overwritten per chunk (`reset` clears and re-sizes), so reuse is
/// invisible in the report bytes; `run_reference` deliberately keeps its
/// per-call allocations as the executable specification.
#[derive(Debug, Clone, Default)]
struct DispatchScratch {
    dependents: Vec<Vec<TaskId>>,
    indeg: Vec<usize>,
    ready_time: Vec<f64>,
    heap: BinaryHeap<ReadyKey>,
}

impl DispatchScratch {
    /// Clear for a chunk of `n` tasks, keeping prior capacity (inner
    /// adjacency vectors included).
    fn reset(&mut self, n: usize) {
        let keep = self.dependents.len().min(n);
        for d in &mut self.dependents[..keep] {
            d.clear();
        }
        self.dependents.truncate(n);
        self.dependents.resize_with(n, Vec::new);
        self.indeg.clear();
        self.indeg.resize(n, 0);
        self.ready_time.clear();
        self.ready_time.resize(n, 0.0);
        self.heap.clear();
    }
}

/// The simulator: owns resource clocks so multi-round simulations can feed
/// successive DAG chunks while time accumulates.
///
/// Chunk semantics: each [`Simulator::run`] call models a DAG the
/// controller *released* at the current clock — no task of a later chunk
/// may start before every earlier chunk finished being released (the
/// release floor).  This is what makes clocks resumable across re-planning
/// boundaries: a post-dropout chunk on a previously idle device cannot
/// time-travel to t = 0.
/// Snapshot of the simulator's resource clocks (see
/// [`Simulator::clock_state`]).  Links are sorted by `(from, to)` so the
/// serialized form is deterministic.
#[derive(Debug, Clone, PartialEq)]
pub struct ClockState {
    pub device_free: Vec<f64>,
    pub link_free: Vec<(usize, usize, f64)>,
    pub dead: Vec<bool>,
    pub now: f64,
}

#[derive(Debug, Clone)]
pub struct Simulator {
    /// Shared, immutable cluster description.  An `Arc` so fleet-scale
    /// callers (thousands of concurrent jobs over one 10k-device pool)
    /// share a single rate matrix instead of cloning ~O(n²) floats per
    /// simulator; single-job callers pay one refcount and nothing else.
    cluster: Arc<ClusterConfig>,
    lut: CostLut,
    device_free: Vec<f64>,
    link_free: BTreeMap<(usize, usize), f64>,
    /// Scenario-derived rate windows (empty for a healthy cluster).
    perturb: scenario::Compiled,
    /// Fail-stopped devices (set via [`Simulator::drop_device`]).
    dead: Vec<bool>,
    /// Cluster rates/speeds checked once (first chunk); a zero, negative or
    /// NaN rate would otherwise surface as an inf/NaN makespan.
    validated: bool,
    /// Reusable dispatch buffers (see [`DispatchScratch`]).
    scratch: DispatchScratch,
    pub now: f64,
}

impl Simulator {
    pub fn new(cluster: ClusterConfig, lut: CostLut) -> Self {
        Self::new_shared(Arc::new(cluster), lut)
    }

    /// [`Simulator::new`] over an already-shared cluster: no copy of the
    /// rate matrix, just a refcount bump.  The fleet layer builds one
    /// `Arc` per run and hands it to every job's simulator.
    pub fn new_shared(cluster: Arc<ClusterConfig>, lut: CostLut) -> Self {
        let n = cluster.len();
        Simulator {
            perturb: scenario::Compiled::empty(n),
            dead: vec![false; n],
            cluster,
            lut,
            device_free: vec![0.0; n],
            link_free: BTreeMap::new(),
            validated: false,
            scratch: DispatchScratch::default(),
            now: 0.0,
        }
    }

    /// A simulator whose clock runs under `scenario`'s straggler and
    /// link-degradation windows.  Dropout events are *not* auto-applied —
    /// the training driver decides when a failure is detected and calls
    /// [`Simulator::drop_device`] (see `train::simulate_scenario`).
    pub fn with_scenario(
        cluster: ClusterConfig,
        lut: CostLut,
        scenario: &Scenario,
    ) -> Result<Self> {
        Self::with_scenario_shared(Arc::new(cluster), lut, scenario)
    }

    /// [`Simulator::with_scenario`] over an already-shared cluster.
    pub fn with_scenario_shared(
        cluster: Arc<ClusterConfig>,
        lut: CostLut,
        scenario: &Scenario,
    ) -> Result<Self> {
        scenario.validate(cluster.len())?;
        let mut sim = Self::new_shared(cluster, lut);
        sim.perturb = scenario.compile(sim.cluster.len());
        Ok(sim)
    }

    /// Skip the one-time cluster validity check in
    /// [`Simulator::run`]'s chunk admission: the caller validated the
    /// shared pool once up front (the fleet does, at `FleetRun`
    /// construction) and re-checking an O(n²) rate matrix per job is
    /// measurable at 10k devices.  Behaviorally inert for valid
    /// clusters — the check is idempotent and error-free on them.
    pub fn assume_validated(&mut self) {
        self.validated = true;
    }

    pub fn lut(&self) -> &CostLut {
        &self.lut
    }

    /// Mark `device` fail-stopped: any later chunk touching it is rejected.
    pub fn drop_device(&mut self, device: usize) {
        self.dead[device] = true;
    }

    pub fn is_alive(&self, device: usize) -> bool {
        !self.dead[device]
    }

    /// Checkpointable clock state: resource clocks, dead set, and the
    /// global clock.  Everything else in the simulator is either derived
    /// from the cluster/scenario (`perturb`), overwritten per chunk
    /// (`scratch`), or behaviorally inert to re-run (`validated`), so this
    /// is sufficient for a byte-identical resume.
    pub fn clock_state(&self) -> ClockState {
        // `link_free` is a BTreeMap, so this iterates in (a, b) order
        // already — the snapshot stays byte-identical to the old
        // explicitly-sorted capture.
        let link_free: Vec<(usize, usize, f64)> =
            self.link_free.iter().map(|(&(a, b), &t)| (a, b, t)).collect();
        ClockState {
            device_free: self.device_free.clone(),
            link_free,
            dead: self.dead.clone(),
            now: self.now,
        }
    }

    /// Restore clocks captured by [`Simulator::clock_state`] onto a fresh
    /// simulator built from the same cluster + scenario.
    pub fn restore_clocks(&mut self, state: &ClockState) -> Result<()> {
        let n = self.cluster.len();
        if state.device_free.len() != n || state.dead.len() != n {
            return Err(Error::Schedule(format!(
                "clock state for {} devices restored onto a {n}-device cluster",
                state.device_free.len()
            )));
        }
        for &(a, b, _) in &state.link_free {
            if a >= n || b >= n {
                return Err(Error::Schedule(format!(
                    "clock state references link ({a}, {b}) outside a {n}-device cluster"
                )));
            }
        }
        self.device_free.clone_from(&state.device_free);
        self.link_free =
            state.link_free.iter().map(|&(a, b, t)| ((a, b), t)).collect();
        self.dead.clone_from(&state.dead);
        self.now = state.now;
        Ok(())
    }

    /// Nominal duration (no scenario windows applied).  Safe to divide by
    /// the link rate: [`Simulator::check_chunk`] validated the cluster.
    fn duration(&self, task: &Task) -> f64 {
        match task.kind {
            Kind::Compute { device, op } => {
                self.lut.op_seconds(op, self.cluster.devices[device].compute_speed)
            }
            Kind::Transfer { from, to, bytes } => {
                bytes as f64 / self.cluster.rate_bytes_per_s[from][to]
                    + self.cluster.link_latency_s
            }
        }
    }

    /// Finish time of `task` starting at `start`, integrating the
    /// scenario's piecewise-constant rate multipliers for its resource.
    fn finish_time(&self, task: &Task, start: f64) -> Result<f64> {
        let base = self.duration(task);
        match task.kind {
            Kind::Compute { device, .. } => {
                scenario::finish_after(self.perturb.device(device), start, base)
            }
            Kind::Transfer { from, to, .. } => {
                scenario::finish_after(self.perturb.link(from, to), start, base)
            }
        }
    }

    /// Earliest start of `task` given its dep-readiness, its resource's
    /// clock, and the chunk release floor.  Both dispatch implementations
    /// call exactly this, so their arithmetic is identical.
    fn feasible_start(&self, task: &Task, ready_time: f64, release: f64) -> f64 {
        let res_free = match task.resource() {
            Resource::Device(d) => self.device_free[d],
            Resource::Link(a, b) => *self.link_free.get(&(a, b)).unwrap_or(&0.0),
        };
        res_free.max(ready_time).max(release)
    }

    /// Chunk admission: cluster validity (once), DAG validity, and no task
    /// touching a fail-stopped device.
    fn check_chunk(&mut self, tasks: &[Task]) -> Result<()> {
        if !self.validated {
            self.cluster.validate().map_err(|e| {
                Error::Schedule(format!("cluster rejected by the simulator: {e}"))
            })?;
            self.validated = true;
        }
        crate::pipeline::validate_dag(tasks)?;
        for t in tasks {
            let touched_dead = match t.kind {
                Kind::Compute { device, .. } => self.dead[device],
                Kind::Transfer { from, to, .. } => self.dead[from] || self.dead[to],
            };
            if touched_dead {
                return Err(Error::Schedule(format!(
                    "task {} targets a fail-stopped device (re-plan required)",
                    t.id
                )));
            }
        }
        Ok(())
    }

    /// Execute one DAG chunk; resource clocks persist across calls.
    /// Dispatch is the lazily re-keyed binary heap described in the module
    /// docs — O(T log T) over the chunk's T tasks.
    pub fn run(&mut self, tasks: &[Task]) -> Result<SimReport> {
        self.check_chunk(tasks)?;
        // Release floor: this chunk was handed to the cluster at the
        // current clock; nothing in it may start earlier.
        let release = self.now;
        let n = tasks.len();
        let mut finish = vec![f64::NAN; n];
        let mut start = vec![f64::NAN; n];
        // Dispatch tables come from the reusable scratch (taken out of
        // `self` so the resource-clock methods stay borrowable, put back
        // below; an error path drops it and the next chunk re-allocates).
        let mut scr = std::mem::take(&mut self.scratch);
        scr.reset(n);
        for (i, t) in tasks.iter().enumerate() {
            scr.indeg[i] = t.deps.len();
        }
        for t in tasks {
            for &d in &t.deps {
                scr.dependents[d].push(t.id);
            }
        }
        // scr.ready_time[i] = max over scheduled deps' finishes; final by
        // the time task i enters the heap.
        let mut device_busy = vec![0.0; self.cluster.len()];
        let mut link_bytes: BTreeMap<(usize, usize), usize> = BTreeMap::new();
        let mut scheduled = 0usize;

        for (i, t) in tasks.iter().enumerate() {
            if scr.indeg[i] == 0 {
                scr.heap.push(ReadyKey {
                    start: self.feasible_start(t, scr.ready_time[i], release),
                    id: i,
                });
            }
        }

        while scheduled < n {
            let Some(key) = scr.heap.pop() else {
                return Err(Error::Schedule(
                    "deadlock: no ready tasks but DAG unfinished".into(),
                ));
            };
            let tid = key.id;
            let t = &tasks[tid];
            let s = self.feasible_start(t, scr.ready_time[tid], release);
            if s > key.start {
                // Stale key: the resource clock advanced after this entry
                // was pushed.  Re-insert at the true feasible start.
                scr.heap.push(ReadyKey { start: s, id: tid });
                continue;
            }
            let f = self.finish_time(t, s)?;
            start[tid] = s;
            finish[tid] = f;
            match t.kind {
                Kind::Compute { device, .. } => {
                    self.device_free[device] = f;
                    // Occupied time, including any scenario-induced stall.
                    device_busy[device] += f - s;
                }
                Kind::Transfer { from, to, bytes } => {
                    self.link_free.insert((from, to), f);
                    *link_bytes.entry((from, to)).or_insert(0) += bytes;
                }
            }
            self.now = self.now.max(f);
            scheduled += 1;
            for di in 0..scr.dependents[tid].len() {
                let dep = scr.dependents[tid][di];
                scr.ready_time[dep] = scr.ready_time[dep].max(f);
                scr.indeg[dep] -= 1;
                if scr.indeg[dep] == 0 {
                    scr.heap.push(ReadyKey {
                        start: self.feasible_start(&tasks[dep], scr.ready_time[dep], release),
                        id: dep,
                    });
                }
            }
        }

        self.scratch = scr;
        Ok(SimReport {
            makespan: self.now,
            release,
            window_s: self.now - release,
            finish,
            start,
            device_busy,
            link_bytes,
        })
    }

    /// The seed O(T·R) greedy dispatch — rescans the whole ready list every
    /// step.  Kept as the executable specification of the scheduling
    /// policy: [`Simulator::run`] must produce byte-identical reports
    /// (`tests/scale_and_robustness.rs` compares them on random DAGs and on
    /// the determinism-golden scenarios).  Not for production use.
    #[doc(hidden)]
    pub fn run_reference(&mut self, tasks: &[Task]) -> Result<SimReport> {
        self.check_chunk(tasks)?;
        let release = self.now;
        let n = tasks.len();
        let mut finish = vec![f64::NAN; n];
        let mut start = vec![f64::NAN; n];
        let mut indeg: Vec<usize> = tasks.iter().map(|t| t.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for t in tasks {
            for &d in &t.deps {
                dependents[d].push(t.id);
            }
        }
        let mut ready_time = vec![0.0f64; n];
        let mut ready: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut device_busy = vec![0.0; self.cluster.len()];
        let mut link_bytes: BTreeMap<(usize, usize), usize> = BTreeMap::new();
        let mut scheduled = 0usize;

        while scheduled < n {
            if ready.is_empty() {
                return Err(Error::Schedule(
                    "deadlock: no ready tasks but DAG unfinished".into(),
                ));
            }
            // Pick the ready task with the earliest feasible start
            // (tie-break: lowest id = generation order).
            let mut best: Option<(f64, usize, usize)> = None; // (start, id, ready_idx)
            for (ri, &tid) in ready.iter().enumerate() {
                let s = self.feasible_start(&tasks[tid], ready_time[tid], release);
                if best.map_or(true, |(bs, bid, _)| (s, tid) < (bs, bid)) {
                    best = Some((s, tid, ri));
                }
            }
            let (s, tid, ri) = best.unwrap();
            ready.swap_remove(ri);
            let t = &tasks[tid];
            let f = self.finish_time(t, s)?;
            start[tid] = s;
            finish[tid] = f;
            match t.kind {
                Kind::Compute { device, .. } => {
                    self.device_free[device] = f;
                    device_busy[device] += f - s;
                }
                Kind::Transfer { from, to, bytes } => {
                    self.link_free.insert((from, to), f);
                    *link_bytes.entry((from, to)).or_insert(0) += bytes;
                }
            }
            self.now = self.now.max(f);
            scheduled += 1;
            for &dep in &dependents[tid] {
                ready_time[dep] = ready_time[dep].max(f);
                indeg[dep] -= 1;
                if indeg[dep] == 0 {
                    ready.push(dep);
                }
            }
        }

        Ok(SimReport {
            makespan: self.now,
            release,
            window_s: self.now - release,
            finish,
            start,
            device_busy,
            link_bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::ModelHyper;
    use crate::model::ModelMeta;
    use crate::pipeline::{Kind, Op, Task};

    fn meta() -> ModelMeta {
        ModelMeta {
            hyper: ModelHyper {
                name: "t".into(), vocab: 512, hidden: 64, layers: 4, heads: 4,
                ffn: 256, bottleneck: 16, seq: 32, batch: 4, init_std: 0.02,
            },
            embed_params: 32768,
            block_backbone_params: 100_000,
            block_adapter_params: 2128,
            head_params: 130,
        }
    }

    fn sim(n: usize) -> Simulator {
        Simulator::new(
            ClusterConfig::homogeneous(n, 1e6),
            CostLut::analytic(&meta(), 1.0),
        )
    }

    fn compute(id: usize, device: usize, n: usize, deps: Vec<usize>) -> Task {
        Task { id, kind: Kind::Compute { device, op: Op::BlockFwd { n } }, deps, step: 0, round: 0 }
    }

    #[test]
    fn chain_is_sequential() {
        let mut s = sim(2);
        let tasks = vec![
            compute(0, 0, 1, vec![]),
            compute(1, 1, 1, vec![0]),
        ];
        let r = s.run(&tasks).unwrap();
        assert!(r.start[1] >= r.finish[0]);
        assert!((r.makespan - r.finish[1]).abs() < 1e-12);
    }

    #[test]
    fn independent_tasks_on_different_devices_overlap() {
        let mut s = sim(2);
        let tasks = vec![compute(0, 0, 4, vec![]), compute(1, 1, 4, vec![])];
        let r = s.run(&tasks).unwrap();
        let single = s.lut().op_seconds(Op::BlockFwd { n: 4 }, 1.0);
        assert!((r.makespan - single).abs() < 1e-9, "should run in parallel");
    }

    #[test]
    fn same_device_serializes() {
        let mut s = sim(1);
        let tasks = vec![compute(0, 0, 2, vec![]), compute(1, 0, 2, vec![])];
        let r = s.run(&tasks).unwrap();
        let one = s.lut().op_seconds(Op::BlockFwd { n: 2 }, 1.0);
        assert!((r.makespan - 2.0 * one).abs() < 1e-9);
        assert!((r.utilization()[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_time_is_bytes_over_rate_plus_latency() {
        let mut cl = ClusterConfig::homogeneous(2, 1000.0);
        cl.link_latency_s = 0.5;
        let mut s = Simulator::new(cl, CostLut::analytic(&meta(), 1.0));
        let tasks = vec![Task {
            id: 0,
            kind: Kind::Transfer { from: 0, to: 1, bytes: 2000 },
            deps: vec![],
            step: 0,
            round: 0,
        }];
        let r = s.run(&tasks).unwrap();
        assert!((r.makespan - 2.5).abs() < 1e-9);
        assert_eq!(r.link_bytes[&(0, 1)], 2000);
    }

    #[test]
    fn greedy_prefers_ready_over_blocked() {
        // Device 0: long task A; device 1: B depends on A, C independent.
        // C must run before B on device 1.
        let mut s = sim(2);
        let tasks = vec![
            compute(0, 0, 8, vec![]),
            compute(1, 1, 1, vec![0]), // blocked on A
            compute(2, 1, 1, vec![]),  // free
        ];
        let r = s.run(&tasks).unwrap();
        assert!(r.start[2] < r.start[1]);
        assert!((r.start[2] - 0.0).abs() < 1e-12);
    }

    #[test]
    fn clocks_persist_across_chunks() {
        let mut s = sim(1);
        let t1 = vec![compute(0, 0, 2, vec![])];
        let r1 = s.run(&t1).unwrap();
        let t2 = vec![compute(0, 0, 2, vec![])];
        let r2 = s.run(&t2).unwrap();
        assert!(r2.start[0] >= r1.finish[0]);
        assert!(s.now >= r2.finish[0] - 1e-12);
    }

    #[test]
    fn clock_state_round_trips_onto_a_fresh_simulator() {
        let mut s = sim(2);
        let chunk = vec![
            compute(0, 0, 2, vec![]),
            compute(1, 1, 2, vec![0]),
            Task {
                id: 2,
                kind: Kind::Transfer { from: 0, to: 1, bytes: 500 },
                deps: vec![0],
                step: 0,
                round: 0,
            },
        ];
        s.run(&chunk).unwrap();
        s.drop_device(1);
        let state = s.clock_state();

        let mut fresh = sim(2);
        fresh.restore_clocks(&state).unwrap();
        assert_eq!(fresh.clock_state(), state);
        assert!(!fresh.is_alive(1));
        assert_eq!(fresh.now.to_bits(), s.now.to_bits());

        // A mismatched cluster size or out-of-range link is rejected.
        assert!(sim(3).restore_clocks(&state).is_err());
        let mut bad = state.clone();
        bad.link_free.push((7, 0, 1.0));
        assert!(sim(2).restore_clocks(&bad).is_err());
    }

    #[test]
    fn later_chunk_utilization_uses_its_own_window() {
        // Two equal chunks on one device: both are fully busy inside their
        // windows, so both must report utilization 1.0.  (The seed divided
        // the second chunk's busy time by the *global* clock — 0.5.)
        let mut s = sim(1);
        let r1 = s.run(&[compute(0, 0, 2, vec![])]).unwrap();
        let r2 = s.run(&[compute(0, 0, 2, vec![])]).unwrap();
        assert!((r1.utilization()[0] - 1.0).abs() < 1e-9);
        assert!((r2.utilization()[0] - 1.0).abs() < 1e-9, "{}", r2.utilization()[0]);
        assert!((r2.release - r1.makespan).abs() < 1e-12);
        assert!((r2.window_s - (r2.makespan - r2.release)).abs() < 1e-12);
        // The global-clock ratio is still available, and smaller.
        assert!(r2.global_utilization()[0] < r2.utilization()[0]);
    }

    #[test]
    fn zero_or_negative_link_rate_is_rejected_up_front() {
        let mut cl = ClusterConfig::homogeneous(2, 1000.0);
        cl.rate_bytes_per_s[0][1] = 0.0;
        let mut s = Simulator::new(cl, CostLut::analytic(&meta(), 1.0));
        let err = s.run(&[compute(0, 0, 1, vec![])]).unwrap_err();
        assert!(matches!(err, Error::Schedule(_)), "got {err}");

        let mut cl2 = ClusterConfig::homogeneous(2, 1000.0);
        cl2.rate_bytes_per_s[1][0] = -5.0;
        let mut s2 = Simulator::new(cl2, CostLut::analytic(&meta(), 1.0));
        assert!(s2.run(&[compute(0, 0, 1, vec![])]).is_err());

        let mut cl3 = ClusterConfig::homogeneous(2, 1000.0);
        cl3.devices[1].compute_speed = f64::NAN;
        let mut s3 = Simulator::new(cl3, CostLut::analytic(&meta(), 1.0));
        assert!(s3.run(&[compute(0, 0, 1, vec![])]).is_err());
    }

    #[test]
    fn speed_difference_shows_in_makespan() {
        let mut cl = ClusterConfig::homogeneous(2, 1e9);
        cl.devices[1].compute_speed = 0.5;
        let mut s = Simulator::new(cl, CostLut::analytic(&meta(), 1.0));
        let tasks = vec![compute(0, 0, 2, vec![]), compute(1, 1, 2, vec![])];
        let r = s.run(&tasks).unwrap();
        assert!((r.finish[1] / r.finish[0] - 2.0).abs() < 1e-9);
    }

    #[test]
    fn straggler_window_slows_compute() {
        let cl = ClusterConfig::homogeneous(1, 1e6);
        let lut = CostLut::analytic(&meta(), 1.0);
        let healthy = lut.op_seconds(Op::BlockFwd { n: 2 }, 1.0);
        let sc = Scenario {
            name: "s".into(),
            events: vec![ScenarioEvent::Straggler {
                device: 0,
                t_start: 0.0,
                t_end: 1e9, // covers the whole run
                factor: 0.5,
            }],
        };
        let mut s = Simulator::with_scenario(cl, lut, &sc).unwrap();
        let r = s.run(&[compute(0, 0, 2, vec![])]).unwrap();
        assert!(
            (r.makespan - 2.0 * healthy).abs() < 1e-9,
            "half speed should double the makespan: {} vs {healthy}",
            r.makespan
        );
        // Busy time counts occupancy (the stall is real wall-clock).
        assert!((r.device_busy[0] - r.makespan).abs() < 1e-12);
    }

    #[test]
    fn link_outage_stalls_transfer_until_window_lifts() {
        let mut cl = ClusterConfig::homogeneous(2, 1000.0);
        cl.link_latency_s = 0.0;
        let sc = Scenario {
            name: "o".into(),
            events: vec![ScenarioEvent::LinkDegrade {
                from: 0,
                to: 1,
                t_start: 1.0,
                t_end: 4.0,
                factor: 0.0,
            }],
        };
        let mut s = Simulator::with_scenario(cl, CostLut::analytic(&meta(), 1.0), &sc).unwrap();
        // 2000 bytes at 1000 B/s = 2s of work: 1s before the outage, stall
        // [1, 4), remaining 1s after -> finish at 5.
        let tasks = vec![Task {
            id: 0,
            kind: Kind::Transfer { from: 0, to: 1, bytes: 2000 },
            deps: vec![],
            step: 0,
            round: 0,
        }];
        let r = s.run(&tasks).unwrap();
        assert!((r.finish[0] - 5.0).abs() < 1e-9, "finish {}", r.finish[0]);
    }

    #[test]
    fn later_chunks_never_start_before_their_release() {
        // Chunk 1 busies device 0; chunk 2 runs on the *idle* device 1.
        // Without the release floor chunk 2 would start at t = 0 — i.e.
        // before the re-plan that produced it even happened.
        let mut s = sim(2);
        let r1 = s.run(&[compute(0, 0, 4, vec![])]).unwrap();
        let r2 = s.run(&[compute(0, 1, 1, vec![])]).unwrap();
        assert!(
            r2.start[0] >= r1.finish[0] - 1e-12,
            "chunk 2 time-traveled: starts {} before release {}",
            r2.start[0],
            r1.finish[0]
        );
    }

    #[test]
    fn dropped_device_rejects_new_chunks() {
        let mut s = sim(2);
        s.run(&[compute(0, 0, 1, vec![])]).unwrap();
        s.drop_device(0);
        assert!(!s.is_alive(0) && s.is_alive(1));
        assert!(s.run(&[compute(0, 0, 1, vec![])]).is_err());
        // Transfers touching the dead device are rejected too.
        let t = Task {
            id: 0,
            kind: Kind::Transfer { from: 1, to: 0, bytes: 8 },
            deps: vec![],
            step: 0,
            round: 0,
        };
        assert!(s.run(&[t]).is_err());
        // The surviving device keeps working, with clocks intact.
        let r = s.run(&[compute(0, 1, 1, vec![])]).unwrap();
        assert!(r.start[0] >= 0.0);
    }

    #[test]
    fn scratch_reuse_is_invisible_across_chunks_of_changing_size() {
        // Chunks of growing then shrinking task counts through one
        // simulator (scratch reused across all three) vs the reference
        // scan (allocates per call) on a clone with identical clocks.
        // Reports must match byte for byte.
        let chunks: Vec<Vec<Task>> = vec![
            vec![compute(0, 0, 2, vec![])],
            vec![
                compute(0, 0, 1, vec![]),
                compute(1, 1, 2, vec![0]),
                compute(2, 0, 1, vec![0]),
            ],
            vec![compute(0, 1, 3, vec![])],
        ];
        let mut reused = sim(2);
        let mut fresh = reused.clone();
        for (k, chunk) in chunks.iter().enumerate() {
            let ra = reused.run(chunk).unwrap();
            let rb = fresh.run_reference(chunk).unwrap();
            assert_eq!(ra.start, rb.start, "chunk {k}");
            assert_eq!(ra.finish, rb.finish, "chunk {k}");
            assert_eq!(ra.device_busy, rb.device_busy, "chunk {k}");
            assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits(), "chunk {k}");
        }
    }

    #[test]
    fn heap_and_reference_dispatch_agree_on_a_contended_dag() {
        // A small DAG with resource contention and cross-device deps; the
        // heavier differential coverage lives in the integration battery.
        let tasks = vec![
            compute(0, 0, 3, vec![]),
            compute(1, 1, 1, vec![]),
            compute(2, 0, 1, vec![1]),
            compute(3, 1, 2, vec![0]),
            compute(4, 0, 1, vec![2, 3]),
        ];
        let mut a = sim(2);
        let mut b = sim(2);
        let ra = a.run(&tasks).unwrap();
        let rb = b.run_reference(&tasks).unwrap();
        assert_eq!(ra.start, rb.start);
        assert_eq!(ra.finish, rb.finish);
        assert_eq!(ra.device_busy, rb.device_busy);
        assert_eq!(ra.makespan.to_bits(), rb.makespan.to_bits());
    }
}
