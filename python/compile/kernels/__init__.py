"""L1 Pallas kernels for the RingAda model (build-time only).

Every kernel is a ``jax.custom_vjp`` whose forward is a Pallas kernel
(``interpret=True`` — see DESIGN.md §8) and whose backward is either a
Pallas kernel (adapter, layernorm) or recompute-based jnp math (attention).
``ref.py`` holds the pure-jnp oracles used by the pytest suite.
"""

from .adapter import adapter, adapter_param_count
from .attention import mha
from .common import gelu, gelu_grad
from .layernorm import layernorm

__all__ = [
    "adapter",
    "adapter_param_count",
    "mha",
    "gelu",
    "gelu_grad",
    "layernorm",
]
