"""Pure-jnp oracles for every L1 kernel — the CORE correctness signal.

Each function here is the straightforward, un-tiled jnp implementation of
the corresponding Pallas kernel.  pytest (``python/tests/``) sweeps shapes
with hypothesis and asserts ``allclose`` between kernel and oracle for both
values and VJPs (the oracles are plain-jnp, so ``jax.vjp`` differentiates
them directly).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import gelu

LN_EPS = 1e-5


def adapter_ref(x, wd, bd, wu, bu):
    """Serial adapter (paper Eq. (1)): ``x + GELU(x·wd + bd)·wu + bu``."""
    h = gelu(jnp.dot(x, wd) + bd)
    return x + jnp.dot(h, wu) + bu


def layernorm_ref(x, gamma, beta):
    mean = jnp.mean(x, axis=-1, keepdims=True)
    var = jnp.mean((x - mean) ** 2, axis=-1, keepdims=True)
    xhat = (x - mean) * jax.lax.rsqrt(var + LN_EPS)
    return xhat * gamma + beta


def mha_ref(q, k, v):
    """Full-materialization attention; q, k, v: [BH, S, D]."""
    d = q.shape[-1]
    s = jnp.einsum("bqd,bkd->bqk", q, k).astype(jnp.float32) / (d**0.5)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bqk,bkd->bqd", p, v.astype(jnp.float32)).astype(q.dtype)
