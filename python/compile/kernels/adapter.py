"""Fused serial-adapter Pallas kernel — the paper's compute hot-spot (L1).

The serial adapter (paper Eq. (1), Fig. 1) is

    y = x + GELU(x @ W_down + b_down) @ W_up + b_up

inserted after each transformer block's FFN "add & layer norm" sublayer.
During RingAda fine-tuning this is the *only* per-block computation whose
parameters are trained, so both its forward and its backward are first-class
kernels here.

TPU mapping (DESIGN.md §8): the token rows are tiled ``TILE_ROWS × H``
through VMEM while both projection matrices stay VMEM-resident across the
whole row loop (they are tiny: ``2·H·m + m + H`` parameters).  Each grid
step issues two MXU contractions, ``(TILE_ROWS×H)·(H×m)`` and
``(TILE_ROWS×m)·(m×H)``.  The backward kernel accumulates the weight
gradients across grid steps in revisited output blocks — the TPU grid is
sequential per core, so ``+=`` accumulation is well-defined.

Autodiff: ``pallas_call`` has no differentiation rule, so :func:`adapter`
is a ``jax.custom_vjp`` whose forward and backward are *both* Pallas
kernels.  The backward recomputes the bottleneck activations from ``x``
instead of saving them (activation-memory frugality is the paper's whole
point — see DESIGN.md §6).
"""

from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from .common import (
    as_rows,
    cdiv,
    gelu,
    gelu_grad,
    pad_rows,
    pick_row_tile,
)


def _fwd_kernel(x_ref, wd_ref, bd_ref, wu_ref, bu_ref, o_ref):
    x = x_ref[...]
    z = jnp.dot(x, wd_ref[...]) + bd_ref[...][None, :]
    h = gelu(z)
    o_ref[...] = x + jnp.dot(h, wu_ref[...]) + bu_ref[...][None, :]


def _bwd_kernel(
    x_ref,
    wd_ref,
    bd_ref,
    wu_ref,
    gy_ref,
    gx_ref,
    gwd_ref,
    gbd_ref,
    gwu_ref,
    gbu_ref,
):
    step = pl.program_id(0)
    x = x_ref[...]
    gy = gy_ref[...]
    wd = wd_ref[...]
    wu = wu_ref[...]

    # Recompute the bottleneck activations (never stored).
    z = jnp.dot(x, wd) + bd_ref[...][None, :]
    h = gelu(z)

    gh = jnp.dot(gy, wu.T)
    gz = gh * gelu_grad(z)

    gx_ref[...] = gy + jnp.dot(gz, wd.T)

    # Weight-gradient accumulators: all grid steps map to the same output
    # block; initialize on the first step, accumulate afterwards.
    @pl.when(step == 0)
    def _init():
        gwd_ref[...] = jnp.zeros_like(gwd_ref)
        gbd_ref[...] = jnp.zeros_like(gbd_ref)
        gwu_ref[...] = jnp.zeros_like(gwu_ref)
        gbu_ref[...] = jnp.zeros_like(gbu_ref)

    gwd_ref[...] += jnp.dot(x.T, gz)
    gbd_ref[...] += jnp.sum(gz, axis=0)
    gwu_ref[...] += jnp.dot(h.T, gy)
    gbu_ref[...] += jnp.sum(gy, axis=0)


def _adapter_fwd_rows(x, wd, bd, wu, bu):
    rows_total, hidden = x.shape
    tile = pick_row_tile(rows_total)
    x_p, rows = pad_rows(x, tile)
    grid = (cdiv(x_p.shape[0], tile),)

    out = pl.pallas_call(
        _fwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
            pl.BlockSpec(wd.shape, lambda i: (0, 0)),
            pl.BlockSpec(bd.shape, lambda i: (0,)),
            pl.BlockSpec(wu.shape, lambda i: (0, 0)),
            pl.BlockSpec(bu.shape, lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct(x_p.shape, x.dtype),
        interpret=True,
    )(x_p, wd, bd, wu, bu)
    return out[:rows]


def _adapter_bwd_rows(x, wd, bd, wu, gy):
    rows_total, hidden = x.shape
    bneck = wd.shape[1]
    tile = pick_row_tile(rows_total)
    x_p, rows = pad_rows(x, tile)
    gy_p, _ = pad_rows(gy, tile)
    grid = (cdiv(x_p.shape[0], tile),)
    acc = x.dtype  # accumulate in the input dtype (f32 in this codebase)

    gx, gwd, gbd, gwu, gbu = pl.pallas_call(
        _bwd_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
            pl.BlockSpec(wd.shape, lambda i: (0, 0)),
            pl.BlockSpec(bd.shape, lambda i: (0,)),
            pl.BlockSpec(wu.shape, lambda i: (0, 0)),
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((tile, hidden), lambda i: (i, 0)),
            pl.BlockSpec(wd.shape, lambda i: (0, 0)),
            pl.BlockSpec(bd.shape, lambda i: (0,)),
            pl.BlockSpec((bneck, hidden), lambda i: (0, 0)),
            pl.BlockSpec((hidden,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct(x_p.shape, acc),
            jax.ShapeDtypeStruct(wd.shape, acc),
            jax.ShapeDtypeStruct(bd.shape, acc),
            jax.ShapeDtypeStruct((bneck, hidden), acc),
            jax.ShapeDtypeStruct((hidden,), acc),
        ],
        interpret=True,
    )(x_p, wd, bd, wu, gy_p)
    return gx[:rows], gwd, gbd, gwu, gbu


@jax.custom_vjp
def adapter(x, wd, bd, wu, bu):
    """Serial adapter ``y = x + GELU(x·wd + bd)·wu + bu``.

    ``x`` may be ``[..., H]``; ``wd: [H, m]``, ``bd: [m]``, ``wu: [m, H]``,
    ``bu: [H]``.  Differentiable w.r.t. every argument.
    """
    rows, shape = as_rows(x)
    return _adapter_fwd_rows(rows, wd, bd, wu, bu).reshape(shape)


def _vjp_fwd(x, wd, bd, wu, bu):
    y = adapter(x, wd, bd, wu, bu)
    # Residuals: only the *inputs* — the bottleneck activations are
    # recomputed by the backward kernel.
    return y, (x, wd, bd, wu)


def _vjp_bwd(res, gy):
    x, wd, bd, wu = res
    rows_x, shape = as_rows(x)
    rows_gy, _ = as_rows(gy)
    gx, gwd, gbd, gwu, gbu = _adapter_bwd_rows(rows_x, wd, bd, wu, rows_gy)
    return gx.reshape(shape), gwd, gbd, gwu, gbu


adapter.defvjp(_vjp_fwd, _vjp_bwd)


def adapter_param_count(hidden: int, bottleneck: int) -> int:
    """Trainable parameters per adapter module."""
    return 2 * hidden * bottleneck + bottleneck + hidden
