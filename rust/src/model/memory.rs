//! Per-device memory accounting — the model behind Table I's first column.
//!
//! The paper's memory story (§II.B, §III, Table I):
//!
//! * **Single** pays for the *whole* model's weights plus full-depth
//!   activations for backprop plus optimizer state for every adapter.
//! * **PipeAdapter** (PipeDream-style) shards weights across devices but
//!   must (a) keep activations for every in-flight microbatch and (b) stash
//!   one weight *version* per in-flight batch so each batch sees consistent
//!   weights across its forward and backward pass.
//! * **RingAda** shards weights, keeps **one** weight version (no staleness
//!   by construction), stores backprop activations only for blocks at or
//!   above the terminator (backward early-stop), and streams forwards on
//!   frozen-prefix devices (activations are released once sent).
//!
//! All formulas are pure functions of [`ModelMeta`] + an assignment + scheme,
//! so the accounting is unit-testable without touching PJRT.

use super::ModelMeta;
use crate::config::Scheme;

/// Bytes per f32 parameter of Adam state (m and v vectors).
const ADAM_STATE_FACTOR: usize = 2;
const F32: usize = 4;

/// Per-activation-tensor count of *intermediate* tensors a block's backward
/// needs when training adapters.  The recompute-based `block_bwd` only
/// stores the block *input* across the fwd→bwd window; intra-block
/// intermediates are transient.  We charge `1` stored activation per block
/// in the backward region plus `PEAK_TRANSIENT` transient tensors while a
/// block is actually executing (the XLA-measured working set of
/// `block_fwd`/`block_bwd` for the e2e config is ≈3.1 activations wide).
const PEAK_TRANSIENT: usize = 3;

/// One device's memory breakdown (bytes).
#[derive(Debug, Clone, Default)]
pub struct MemoryBreakdown {
    pub backbone_weights: usize,
    pub adapter_weights: usize,
    pub embed_head_weights: usize,
    pub optimizer_state: usize,
    pub stored_activations: usize,
    pub transient_activations: usize,
    pub stashed_weight_versions: usize,
}

impl MemoryBreakdown {
    pub fn total(&self) -> usize {
        self.backbone_weights
            + self.adapter_weights
            + self.embed_head_weights
            + self.optimizer_state
            + self.stored_activations
            + self.transient_activations
            + self.stashed_weight_versions
    }

    pub fn total_mb(&self) -> f64 {
        self.total() as f64 / (1024.0 * 1024.0)
    }
}

/// Memory model for one experiment.
#[derive(Debug, Clone)]
pub struct MemoryModel {
    meta: ModelMeta,
}

impl MemoryModel {
    pub fn new(meta: ModelMeta) -> Self {
        MemoryModel { meta }
    }

    pub fn meta(&self) -> &ModelMeta {
        &self.meta
    }

    /// Memory for a device holding `blocks` transformer blocks under the
    /// given scheme.
    ///
    /// * `unfrozen_on_device` — how many of this device's adapters are
    ///   currently unfrozen (RingAda; for the baselines pass `blocks`).
    /// * `in_flight` — concurrently live microbatches on this device
    ///   (PipeAdapter: pipeline depth; others: 1).
    pub fn device(
        &self,
        scheme: Scheme,
        blocks: usize,
        unfrozen_on_device: usize,
        in_flight: usize,
    ) -> MemoryBreakdown {
        let m = &self.meta;
        let act = m.activation_bytes();
        let backbone = blocks * m.block_backbone_params * F32;
        let adapters = blocks * m.block_adapter_params * F32;
        // Every client hosts a copy of Emb and Hed (paper §III.A).
        let embed_head = (m.embed_params + m.head_params) * F32;

        match scheme {
            Scheme::Single => {
                // One device holds everything; all adapters trainable.
                let trainable = m.hyper.layers * m.block_adapter_params + m.head_params;
                MemoryBreakdown {
                    backbone_weights: m.hyper.layers * m.block_backbone_params * F32,
                    adapter_weights: m.hyper.layers * m.block_adapter_params * F32,
                    embed_head_weights: embed_head,
                    optimizer_state: trainable * ADAM_STATE_FACTOR * F32,
                    // Full-depth backprop: one stored input per block.
                    stored_activations: m.hyper.layers * act,
                    transient_activations: PEAK_TRANSIENT * act,
                    stashed_weight_versions: 0,
                }
            }
            Scheme::PipeAdapter => {
                let trainable = blocks * m.block_adapter_params + m.head_params;
                MemoryBreakdown {
                    backbone_weights: backbone,
                    adapter_weights: adapters,
                    embed_head_weights: embed_head,
                    optimizer_state: trainable * ADAM_STATE_FACTOR * F32,
                    // One stored activation per block per in-flight batch.
                    stored_activations: blocks * act * in_flight.max(1),
                    transient_activations: PEAK_TRANSIENT * act,
                    // Weight stashing: each *extra* in-flight batch pins one
                    // version of this device's trainable weights (adapters;
                    // the frozen backbone needs no versioning).
                    stashed_weight_versions: in_flight.saturating_sub(1)
                        * blocks
                        * m.block_adapter_params
                        * F32,
                }
            }
            Scheme::RingAda => {
                let trainable = unfrozen_on_device * m.block_adapter_params + m.head_params;
                MemoryBreakdown {
                    backbone_weights: backbone,
                    adapter_weights: adapters,
                    embed_head_weights: embed_head,
                    optimizer_state: trainable * ADAM_STATE_FACTOR * F32,
                    // Early stop: only blocks in the backward region store
                    // their input; frozen-prefix blocks stream.
                    stored_activations: unfrozen_on_device * act,
                    transient_activations: PEAK_TRANSIENT * act,
                    stashed_weight_versions: 0, // the design's headline claim
                }
            }
        }
    }

    /// Peak per-device memory across a whole cluster assignment; returns
    /// `(per_device, max)`.
    ///
    /// `assignment[u]` = number of blocks on device `u`;
    /// `unfrozen[u]` = unfrozen adapters on device `u`;
    /// `in_flight` as in [`MemoryModel::device`].
    pub fn cluster_peak(
        &self,
        scheme: Scheme,
        assignment: &[usize],
        unfrozen: &[usize],
        in_flight: usize,
    ) -> (Vec<MemoryBreakdown>, usize) {
        let per: Vec<MemoryBreakdown> = assignment
            .iter()
            .zip(unfrozen)
            .map(|(&b, &u)| self.device(scheme, b, u, in_flight))
            .collect();
        let max = per.iter().map(|b| b.total()).max().unwrap_or(0);
        (per, max)
    }

    /// Average per-device memory in MB — the quantity Table I reports.
    pub fn table1_avg_mb(
        &self,
        scheme: Scheme,
        assignment: &[usize],
        unfrozen: &[usize],
        in_flight: usize,
    ) -> f64 {
        let (per, _) = self.cluster_peak(scheme, assignment, unfrozen, in_flight);
        per.iter().map(|b| b.total_mb()).sum::<f64>() / per.len().max(1) as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::ModelHyper;

    fn meta() -> ModelMeta {
        ModelMeta {
            hyper: ModelHyper {
                name: "t".into(),
                vocab: 8192,
                hidden: 768,
                layers: 12,
                heads: 12,
                ffn: 3072,
                bottleneck: 64,
                seq: 128,
                batch: 8,
                init_std: 0.02,
            },
            embed_params: 8192 * 768 + 128 * 768 + 2 * 768,
            block_backbone_params: 768 * 2304 + 2304 + 768 * 768 + 768 + 2 * 768
                + 768 * 3072 + 3072 + 3072 * 768 + 768 + 2 * 768,
            block_adapter_params: 2 * 768 * 64 + 64 + 768,
            head_params: 768 * 2 + 2,
        }
    }

    #[test]
    fn single_uses_most_memory() {
        let mm = MemoryModel::new(meta());
        let assignment = [3usize, 3, 3, 3];
        let unfrozen = [3usize, 3, 3, 3];
        let single = mm.table1_avg_mb(Scheme::Single, &assignment, &unfrozen, 1);
        let pipe = mm.table1_avg_mb(Scheme::PipeAdapter, &assignment, &unfrozen, 4);
        let ring = mm.table1_avg_mb(Scheme::RingAda, &assignment, &[1, 1, 1, 1], 1);
        assert!(single > pipe, "single {single} <= pipe {pipe}");
        assert!(pipe > ring, "pipe {pipe} <= ring {ring}");
    }

    #[test]
    fn ringada_has_no_stashed_versions() {
        let mm = MemoryModel::new(meta());
        let b = mm.device(Scheme::RingAda, 3, 2, 4);
        assert_eq!(b.stashed_weight_versions, 0);
        let p = mm.device(Scheme::PipeAdapter, 3, 3, 4);
        assert!(p.stashed_weight_versions > 0);
    }

    #[test]
    fn ringada_activation_memory_grows_with_unfreezing() {
        let mm = MemoryModel::new(meta());
        let early = mm.device(Scheme::RingAda, 3, 0, 1);
        let late = mm.device(Scheme::RingAda, 3, 3, 1);
        assert!(late.stored_activations > early.stored_activations);
        assert_eq!(early.stored_activations, 0);
    }

    #[test]
    fn breakdown_total_is_sum_of_fields() {
        let mm = MemoryModel::new(meta());
        let b = mm.device(Scheme::PipeAdapter, 2, 2, 3);
        let sum = b.backbone_weights
            + b.adapter_weights
            + b.embed_head_weights
            + b.optimizer_state
            + b.stored_activations
            + b.transient_activations
            + b.stashed_weight_versions;
        assert_eq!(b.total(), sum);
    }

    #[test]
    fn in_flight_scales_pipe_memory_linearly() {
        let mm = MemoryModel::new(meta());
        let b2 = mm.device(Scheme::PipeAdapter, 3, 3, 2);
        let b4 = mm.device(Scheme::PipeAdapter, 3, 3, 4);
        assert!(b4.stored_activations > b2.stored_activations);
        assert!(b4.stashed_weight_versions > b2.stashed_weight_versions);
    }
}
