//! Trace-based discrete-event simulator (the paper's §V evaluation
//! methodology): executes a schedule DAG under resource exclusivity —
//! one compute task at a time per device, one transfer at a time per
//! directed link — with durations from the profiled [`CostLut`] scaled by
//! each device's `C_u^comp` and link rates from `R_{u,u'}`.
//!
//! Scheduling policy: greedy list scheduling; among all ready tasks, start
//! the one with the earliest feasible start time (ties → lowest task id,
//! i.e. generation order).  Scheme *semantics* (pause rule, early stop,
//! in-flight bounds) live entirely in the DAG's dependencies — the
//! simulator never special-cases a scheme.

pub mod lut;

pub use lut::CostLut;

use std::collections::HashMap;

use crate::config::ClusterConfig;
use crate::error::{Error, Result};
use crate::pipeline::{Kind, Resource, Task, TaskId};

/// Simulation output.
#[derive(Debug, Clone)]
pub struct SimReport {
    /// Finish time (s) per task id.
    pub finish: Vec<f64>,
    /// Start time (s) per task id.
    pub start: Vec<f64>,
    /// Makespan: last finish time.
    pub makespan: f64,
    /// Per-device busy seconds (compute only).
    pub device_busy: Vec<f64>,
    /// Total bytes moved per directed link.
    pub link_bytes: HashMap<(usize, usize), usize>,
}

impl SimReport {
    /// Device utilization over the makespan.
    pub fn utilization(&self) -> Vec<f64> {
        self.device_busy
            .iter()
            .map(|&b| if self.makespan > 0.0 { b / self.makespan } else { 0.0 })
            .collect()
    }
}

/// The simulator: owns resource clocks so multi-round simulations can feed
/// successive DAG chunks while time accumulates.
#[derive(Debug, Clone)]
pub struct Simulator {
    cluster: ClusterConfig,
    lut: CostLut,
    device_free: Vec<f64>,
    link_free: HashMap<(usize, usize), f64>,
    pub now: f64,
}

impl Simulator {
    pub fn new(cluster: ClusterConfig, lut: CostLut) -> Self {
        let n = cluster.len();
        Simulator {
            cluster,
            lut,
            device_free: vec![0.0; n],
            link_free: HashMap::new(),
            now: 0.0,
        }
    }

    pub fn lut(&self) -> &CostLut {
        &self.lut
    }

    fn duration(&self, task: &Task) -> f64 {
        match task.kind {
            Kind::Compute { device, op } => {
                self.lut.op_seconds(op, self.cluster.devices[device].compute_speed)
            }
            Kind::Transfer { from, to, bytes } => {
                bytes as f64 / self.cluster.rate_bytes_per_s[from][to]
                    + self.cluster.link_latency_s
            }
        }
    }

    /// Execute one DAG chunk; resource clocks persist across calls.
    pub fn run(&mut self, tasks: &[Task]) -> Result<SimReport> {
        crate::pipeline::validate_dag(tasks)?;
        let n = tasks.len();
        let mut finish = vec![f64::NAN; n];
        let mut start = vec![f64::NAN; n];
        let mut indeg: Vec<usize> = tasks.iter().map(|t| t.deps.len()).collect();
        let mut dependents: Vec<Vec<TaskId>> = vec![Vec::new(); n];
        for t in tasks {
            for &d in &t.deps {
                dependents[d].push(t.id);
            }
        }
        // ready_time[i] = max over scheduled deps' finishes.
        let mut ready_time = vec![0.0f64; n];
        let mut ready: Vec<TaskId> = (0..n).filter(|&i| indeg[i] == 0).collect();
        let mut device_busy = vec![0.0; self.cluster.len()];
        let mut link_bytes: HashMap<(usize, usize), usize> = HashMap::new();
        let mut scheduled = 0usize;

        while scheduled < n {
            if ready.is_empty() {
                return Err(Error::Schedule(
                    "deadlock: no ready tasks but DAG unfinished".into(),
                ));
            }
            // Pick the ready task with the earliest feasible start
            // (tie-break: lowest id = generation order).
            let mut best: Option<(f64, usize, usize)> = None; // (start, id, ready_idx)
            for (ri, &tid) in ready.iter().enumerate() {
                let t = &tasks[tid];
                let res_free = match t.resource() {
                    Resource::Device(d) => self.device_free[d],
                    Resource::Link(a, b) => *self.link_free.get(&(a, b)).unwrap_or(&0.0),
                };
                let s = res_free.max(ready_time[tid]);
                let key = (s, tid, ri);
                if best.map_or(true, |(bs, bid, _)| (s, tid) < (bs, bid)) {
                    best = Some(key);
                }
            }
            let (s, tid, ri) = best.unwrap();
            ready.swap_remove(ri);
            let t = &tasks[tid];
            let dur = self.duration(t);
            let f = s + dur;
            start[tid] = s;
            finish[tid] = f;
            match t.kind {
                Kind::Compute { device, .. } => {
                    self.device_free[device] = f;
                    device_busy[device] += dur;
                }
                Kind::Transfer { from, to, bytes } => {
                    self.link_free.insert((from, to), f);
                    *link_bytes.entry((from, to)).or_insert(0) += bytes;
                }
            }
            self.now = self.now.max(f);
            scheduled += 1;
            for &dep in &dependents[tid] {
                ready_time[dep] = ready_time[dep].max(f);
                indeg[dep] -= 1;
                if indeg[dep] == 0 {
                    ready.push(dep);
                }
            }
        }

        Ok(SimReport {
            makespan: self.now,
            finish,
            start,
            device_busy,
            link_bytes,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::model::manifest::ModelHyper;
    use crate::model::ModelMeta;
    use crate::pipeline::{Kind, Op, Task};

    fn meta() -> ModelMeta {
        ModelMeta {
            hyper: ModelHyper {
                name: "t".into(), vocab: 512, hidden: 64, layers: 4, heads: 4,
                ffn: 256, bottleneck: 16, seq: 32, batch: 4, init_std: 0.02,
            },
            embed_params: 32768,
            block_backbone_params: 100_000,
            block_adapter_params: 2128,
            head_params: 130,
        }
    }

    fn sim(n: usize) -> Simulator {
        Simulator::new(
            ClusterConfig::homogeneous(n, 1e6),
            CostLut::analytic(&meta(), 1.0),
        )
    }

    fn compute(id: usize, device: usize, n: usize, deps: Vec<usize>) -> Task {
        Task { id, kind: Kind::Compute { device, op: Op::BlockFwd { n } }, deps, step: 0, round: 0 }
    }

    #[test]
    fn chain_is_sequential() {
        let mut s = sim(2);
        let tasks = vec![
            compute(0, 0, 1, vec![]),
            compute(1, 1, 1, vec![0]),
        ];
        let r = s.run(&tasks).unwrap();
        assert!(r.start[1] >= r.finish[0]);
        assert!((r.makespan - r.finish[1]).abs() < 1e-12);
    }

    #[test]
    fn independent_tasks_on_different_devices_overlap() {
        let mut s = sim(2);
        let tasks = vec![compute(0, 0, 4, vec![]), compute(1, 1, 4, vec![])];
        let r = s.run(&tasks).unwrap();
        let single = s.lut().op_seconds(Op::BlockFwd { n: 4 }, 1.0);
        assert!((r.makespan - single).abs() < 1e-9, "should run in parallel");
    }

    #[test]
    fn same_device_serializes() {
        let mut s = sim(1);
        let tasks = vec![compute(0, 0, 2, vec![]), compute(1, 0, 2, vec![])];
        let r = s.run(&tasks).unwrap();
        let one = s.lut().op_seconds(Op::BlockFwd { n: 2 }, 1.0);
        assert!((r.makespan - 2.0 * one).abs() < 1e-9);
        assert!((r.utilization()[0] - 1.0).abs() < 1e-9);
    }

    #[test]
    fn transfer_time_is_bytes_over_rate_plus_latency() {
        let mut cl = ClusterConfig::homogeneous(2, 1000.0);
        cl.link_latency_s = 0.5;
        let mut s = Simulator::new(cl, CostLut::analytic(&meta(), 1.0));
        let tasks = vec![Task {
            id: 0,
            kind: Kind::Transfer { from: 0, to: 1, bytes: 2000 },
            deps: vec![],
            step: 0,
            round: 0,
        }];
        let r = s.run(&tasks).unwrap();
        assert!((r.makespan - 2.5).abs() < 1e-9);
        assert_eq!(r.link_bytes[&(0, 1)], 2000);
    }

    #[test]
    fn greedy_prefers_ready_over_blocked() {
        // Device 0: long task A; device 1: B depends on A, C independent.
        // C must run before B on device 1.
        let mut s = sim(2);
        let tasks = vec![
            compute(0, 0, 8, vec![]),
            compute(1, 1, 1, vec![0]), // blocked on A
            compute(2, 1, 1, vec![]),  // free
        ];
        let r = s.run(&tasks).unwrap();
        assert!(r.start[2] < r.start[1]);
        assert!((r.start[2] - 0.0).abs() < 1e-12);
    }

    #[test]
    fn clocks_persist_across_chunks() {
        let mut s = sim(1);
        let t1 = vec![compute(0, 0, 2, vec![])];
        let r1 = s.run(&t1).unwrap();
        let t2 = vec![compute(0, 0, 2, vec![])];
        let r2 = s.run(&t2).unwrap();
        assert!(r2.start[0] >= r1.finish[0]);
        assert!(s.now >= r2.finish[0] - 1e-12);
    }

    #[test]
    fn speed_difference_shows_in_makespan() {
        let mut cl = ClusterConfig::homogeneous(2, 1e9);
        cl.devices[1].compute_speed = 0.5;
        let mut s = Simulator::new(cl, CostLut::analytic(&meta(), 1.0));
        let tasks = vec![compute(0, 0, 2, vec![]), compute(1, 1, 2, vec![])];
        let r = s.run(&tasks).unwrap();
        assert!((r.finish[1] / r.finish[0] - 2.0).abs() < 1e-9);
    }
}
