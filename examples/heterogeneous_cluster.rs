//! Heterogeneous-cluster scenario: devices with very different compute
//! speeds and link rates.  Shows (a) the coordinator's layer-assignment
//! planner adapting block counts to device capability (paper §IV.1), and
//! (b) the resulting timing advantage over a naive uniform split.
//!
//! ```bash
//! cargo run --release --example heterogeneous_cluster
//! ```

use ringada::coordinator::{Planner, PlannerCosts};
use ringada::prelude::*;
use ringada::sim::CostLut;

fn main() -> Result<()> {
    let mut exp = ExperimentConfig::paper_default("artifacts/tiny");
    // A strongly lopsided smart-home cluster: one hub-class device, one
    // mid-tier, two weak sensors; asymmetric link rates.
    let speeds = [0.4, 0.1, 0.05, 0.08];
    for (d, s) in exp.cluster.devices.iter_mut().zip(speeds) {
        d.compute_speed = s;
    }
    exp.cluster.rate_bytes_per_s = vec![
        vec![0.0, 30e6, 10e6, 10e6],
        vec![30e6, 0.0, 12e6, 8e6],
        vec![10e6, 12e6, 0.0, 25e6],
        vec![10e6, 8e6, 25e6, 0.0],
    ];

    let engine = Engine::load(&exp.artifact_dir)?;
    let meta = ModelMeta::from_manifest(engine.manifest())?;
    let weights = ModelWeights::init(engine.manifest(), 7)?;
    let lut = CostLut::from_engine(&engine, &weights, 2)?;
    let costs = PlannerCosts {
        block_fwd_s: lut.block_fwd_s,
        activation_bytes: meta.activation_bytes(),
    };

    let planner = Planner::new(&meta, &exp.cluster, costs);
    let plan = planner.plan()?;
    let uniform = planner.uniform_plan()?;

    println!("planned assignment (capability-aware):");
    for (pos, (&dev, &(s, e))) in
        plan.assignment.order.iter().zip(&plan.assignment.blocks).enumerate()
    {
        println!(
            "  pos {pos}: device {dev} (speed {:.2}) blocks [{s},{e}) = {} blocks",
            exp.cluster.devices[dev].compute_speed,
            e - s
        );
    }
    println!(
        "bottleneck stage time: planned {:.4}s vs uniform {:.4}s ({:.2}x better)",
        plan.bottleneck_s,
        uniform.bottleneck_s,
        uniform.bottleneck_s / plan.bottleneck_s
    );

    // Train a short run on the planned cluster to show it end to end.
    exp.training.rounds = 10;
    exp.training.local_iters = 2;
    let report = ringada::train::run_scheme(&exp, Scheme::RingAda)?;
    println!(
        "\nRingAda on this cluster: final loss {:.4}, simulated time {:.2}s, util {:?}",
        report.final_loss(),
        report.total_time_s,
        report
            .utilization
            .iter()
            .map(|u| format!("{:.0}%", u * 100.0))
            .collect::<Vec<_>>()
    );
    Ok(())
}
