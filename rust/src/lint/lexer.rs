//! Minimal Rust source lexer for the in-tree linter: strips comments and
//! string/char-literal *contents*, splits each line into a code part and a
//! `//`-comment part, and marks `#[cfg(test)]` / `#[test]` item spans as
//! exempt.
//!
//! This is deliberately not a full Rust lexer — it understands exactly
//! enough token structure (line and nested block comments, plain / raw /
//! byte strings, char literals vs lifetimes, brace nesting) to make the
//! substring rules in [`crate::lint::rules`] sound: a banned pattern inside
//! a comment, a string literal, or a test-only item must never fire, and
//! the same pattern in live library code must always fire.  Line numbers
//! are preserved exactly (multi-line strings and block comments emit empty
//! code lines), so findings point at real source lines.

/// One source line after lexing.
#[derive(Debug, Clone, Default)]
pub struct Line {
    /// Code with comments removed and literal contents blanked: the
    /// delimiting quotes survive (as an empty `""`), their contents do
    /// not, and char literals vanish entirely.  Lifetimes keep their
    /// leading quote.
    pub code: String,
    /// Concatenated text of `//` comments that *start* on this line (the
    /// `//` itself is dropped).  `lint: allow(...)` annotations are parsed
    /// out of this.
    pub comment: String,
}

/// Lexed file: per-line code/comment split plus test-span exemptions.
#[derive(Debug, Clone, Default)]
pub struct Stripped {
    pub lines: Vec<Line>,
    /// `exempt[i]` ⇔ line `i` lies inside (or is) a `#[cfg(test)]` /
    /// `#[test]` item — its braces, the attribute line itself included.
    pub exempt: Vec<bool>,
}

impl Stripped {
    /// Number of source lines (always ≥ 1, even for an empty file).
    pub fn len(&self) -> usize {
        self.lines.len()
    }

    pub fn is_empty(&self) -> bool {
        self.lines.is_empty()
    }
}

/// Lexer state: what kind of region the scan head is inside.
enum State {
    Code,
    LineComment,
    /// Nested block comment at the given depth.
    Block(usize),
    /// Plain or byte string literal.
    Str,
    /// Raw string closed by `"` followed by this many `#`s.
    RawStr(usize),
    /// Char literal body (the opening quote and any escape head were
    /// consumed on entry); ends at the next `'`.
    CharLit,
}

/// Lex `src` into per-line code/comment parts and test-span exemptions.
pub fn strip(src: &str) -> Stripped {
    let chars: Vec<char> = src.chars().collect();
    let n = chars.len();
    let mut lines: Vec<Line> = Vec::new();
    let mut cur = Line::default();
    let mut st = State::Code;
    let mut i = 0usize;

    while i < n {
        let c = chars[i];
        if c == '\n' {
            // A newline terminates line comments; every other state
            // continues onto the next source line.
            if matches!(st, State::LineComment) {
                st = State::Code;
            }
            lines.push(std::mem::take(&mut cur));
            i += 1;
            continue;
        }
        match st {
            State::Code => {
                if c == '/' && i + 1 < n && chars[i + 1] == '/' {
                    st = State::LineComment;
                    i += 2;
                } else if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    st = State::Block(1);
                    i += 2;
                } else if c == '"' {
                    cur.code.push('"');
                    st = State::Str;
                    i += 1;
                } else if (c == 'r' || c == 'b') && !prev_is_ident(&chars, i) {
                    if let Some((hashes, skip)) = raw_string_open(&chars, i) {
                        cur.code.push('"');
                        st = State::RawStr(hashes);
                        i += skip;
                    } else if c == 'b' && i + 1 < n && chars[i + 1] == '"' {
                        cur.code.push('"');
                        st = State::Str;
                        i += 2;
                    } else {
                        cur.code.push(c);
                        i += 1;
                    }
                } else if c == '\'' {
                    // Char literal vs lifetime.  A literal is `'x'` or an
                    // escape `'\…'`; anything else (`'a` in `<'a>`) is a
                    // lifetime and stays in the code stream.  Escape heads
                    // are consumed here so `'\''` and `'\\'` close
                    // correctly in the CharLit state.
                    if i + 1 < n && chars[i + 1] == '\\' {
                        st = State::CharLit;
                        i += 3;
                    } else if i + 2 < n && chars[i + 2] == '\'' {
                        i += 3;
                    } else {
                        cur.code.push('\'');
                        i += 1;
                    }
                } else {
                    cur.code.push(c);
                    i += 1;
                }
            }
            State::LineComment => {
                cur.comment.push(c);
                i += 1;
            }
            State::Block(d) => {
                if c == '/' && i + 1 < n && chars[i + 1] == '*' {
                    st = State::Block(d + 1);
                    i += 2;
                } else if c == '*' && i + 1 < n && chars[i + 1] == '/' {
                    st = if d == 1 { State::Code } else { State::Block(d - 1) };
                    i += 2;
                } else {
                    i += 1;
                }
            }
            State::Str => {
                if c == '\\' {
                    // Skip the escaped char — unless it is a newline
                    // (string continuation), which the top of the loop
                    // must see so line numbers stay aligned.
                    if i + 1 < n && chars[i + 1] == '\n' {
                        i += 1;
                    } else {
                        i += 2;
                    }
                } else if c == '"' {
                    cur.code.push('"');
                    st = State::Code;
                    i += 1;
                } else {
                    i += 1;
                }
            }
            State::RawStr(h) => {
                if c == '"' && closes_raw(&chars, i, h) {
                    cur.code.push('"');
                    st = State::Code;
                    i += 1 + h;
                } else {
                    i += 1;
                }
            }
            State::CharLit => {
                if c == '\'' {
                    st = State::Code;
                }
                i += 1;
            }
        }
    }
    lines.push(cur);
    let exempt = mark_test_spans(&lines);
    Stripped { lines, exempt }
}

fn is_ident_char(c: char) -> bool {
    c.is_ascii_alphanumeric() || c == '_'
}

fn prev_is_ident(chars: &[char], i: usize) -> bool {
    i > 0 && is_ident_char(chars[i - 1])
}

/// If `chars[i..]` opens a raw (or raw byte) string — `r"`, `r#"`, `br##"`,
/// … — return `(hash_count, chars_consumed_by_the_opener)`.
fn raw_string_open(chars: &[char], i: usize) -> Option<(usize, usize)> {
    let mut j = i;
    if chars[j] == 'b' {
        j += 1;
        if j >= chars.len() || chars[j] != 'r' {
            return None;
        }
    }
    j += 1; // past the `r`
    let mut hashes = 0usize;
    while j < chars.len() && chars[j] == '#' {
        hashes += 1;
        j += 1;
    }
    if j < chars.len() && chars[j] == '"' {
        Some((hashes, j + 1 - i))
    } else {
        None
    }
}

/// Does the `"` at `chars[i]` close a raw string with `h` trailing `#`s?
fn closes_raw(chars: &[char], i: usize, h: usize) -> bool {
    if i + h >= chars.len() && h > 0 {
        return false;
    }
    (1..=h).all(|k| i + k < chars.len() && chars[i + k] == '#')
}

/// Mark every line inside a `#[cfg(test)]` / `#[test]` item span.  The
/// attribute sets a pending flag; the next `{` at statement level opens an
/// exempt brace span (a `;` before it — a braceless item — clears the
/// flag).  Spans nest; brace depth is tracked over the *stripped* code, so
/// braces in strings or comments cannot desynchronize it.
fn mark_test_spans(lines: &[Line]) -> Vec<bool> {
    let mut exempt = vec![false; lines.len()];
    let mut depth = 0usize;
    // Paren/bracket depth: a `;` inside `(…)` / `[…]` (e.g. `[u8; 4]`)
    // must not clear a pending attribute.
    let mut pb = 0usize;
    let mut pending = false;
    let mut spans: Vec<usize> = Vec::new();
    for (li, line) in lines.iter().enumerate() {
        if line.code.contains("#[cfg(test)]") || line.code.contains("#[test]") {
            pending = true;
        }
        if pending || !spans.is_empty() {
            exempt[li] = true;
        }
        for ch in line.code.chars() {
            match ch {
                '{' => {
                    depth += 1;
                    if pending {
                        spans.push(depth);
                        pending = false;
                    }
                }
                '}' => {
                    if spans.last() == Some(&depth) {
                        spans.pop();
                    }
                    depth = depth.saturating_sub(1);
                }
                '(' | '[' => pb += 1,
                ')' | ']' => pb = pb.saturating_sub(1),
                ';' => {
                    if pending && pb == 0 {
                        pending = false;
                    }
                }
                _ => {}
            }
        }
        if !spans.is_empty() {
            exempt[li] = true;
        }
    }
    exempt
}

#[cfg(test)]
mod tests {
    use super::*;

    fn codes(src: &str) -> Vec<String> {
        strip(src).lines.into_iter().map(|l| l.code).collect()
    }

    #[test]
    fn comments_are_removed_and_captured() {
        let s = strip("let x = 1; // trailing HashMap note\n// full line\nlet y = 2;\n");
        assert_eq!(s.lines[0].code, "let x = 1; ");
        assert_eq!(s.lines[0].comment, " trailing HashMap note");
        assert_eq!(s.lines[1].code, "");
        assert_eq!(s.lines[1].comment, " full line");
        assert_eq!(s.lines[2].code, "let y = 2;");
    }

    #[test]
    fn block_comments_nest_and_preserve_line_count() {
        let src = "a\n/* one /* two\nstill */ still */ b\nc\n";
        let c = codes(src);
        assert_eq!(c.len(), 5, "trailing newline yields a final empty line");
        assert_eq!(c[0], "a");
        assert_eq!(c[1], "");
        assert_eq!(c[2], " b");
        assert_eq!(c[3], "c");
    }

    #[test]
    fn string_contents_are_blanked() {
        let c = codes("let s = \"HashMap::new() // not code\"; let t = 1;\n");
        assert_eq!(c[0], "let s = \"\"; let t = 1;");
        // Escaped quote stays inside the literal.
        let c = codes("let s = \"a\\\"HashMap\"; x();\n");
        assert_eq!(c[0], "let s = \"\"; x();");
    }

    #[test]
    fn raw_and_byte_strings_are_blanked() {
        let c = codes("let s = r#\"Instant::now() \" inner\"#; y();\n");
        assert_eq!(c[0], "let s = \"\"; y();");
        let c = codes("let s = r\"plain raw\"; z();\n");
        assert_eq!(c[0], "let s = \"\"; z();");
        let c = codes("let s = b\"bytes\"; let r = br#\"raw bytes\"#; w();\n");
        assert_eq!(c[0], "let s = \"\"; let r = \"\"; w();");
    }

    #[test]
    fn multi_line_strings_keep_line_numbers() {
        let src = "let s = \"line one\nline two with HashMap\nend\"; tail();\nnext();\n";
        let c = codes(src);
        assert_eq!(c.len(), 5);
        assert_eq!(c[0], "let s = \"");
        assert_eq!(c[1], "");
        assert_eq!(c[2], "\"; tail();");
        assert_eq!(c[3], "next();");
    }

    #[test]
    fn char_literals_vanish_but_lifetimes_survive() {
        let c = codes("let q = '\"'; let nl = '\\n'; let bs = '\\\\'; let qq = '\\''; f();\n");
        assert_eq!(c[0], "let q = ; let nl = ; let bs = ; let qq = ; f();");
        let c = codes("fn f<'a>(x: &'a str) -> &'a str { x }\n");
        assert_eq!(c[0], "fn f<'a>(x: &'a str) -> &'a str { x }");
    }

    #[test]
    fn identifier_ending_in_r_or_b_is_not_a_raw_string() {
        let c = codes("let var = 1; let grab = 2; f(var, grab);\n");
        assert_eq!(c[0], "let var = 1; let grab = 2; f(var, grab);");
    }

    #[test]
    fn cfg_test_mod_is_exempt_to_its_closing_brace() {
        let src = "\
fn live() {}
#[cfg(test)]
mod tests {
    fn t() { inner(); }
}
fn also_live() {}
";
        let s = strip(src);
        assert!(!s.exempt[0]);
        assert!(s.exempt[1], "the attribute line itself is exempt");
        assert!(s.exempt[2] && s.exempt[3] && s.exempt[4]);
        assert!(!s.exempt[5]);
    }

    #[test]
    fn test_fn_attribute_is_exempt() {
        let src = "\
fn live() {}
#[test]
fn check(x: [u8; 4]) {
    body();
}
fn live2() {}
";
        let s = strip(src);
        assert!(!s.exempt[0]);
        assert!(s.exempt[1] && s.exempt[2] && s.exempt[3] && s.exempt[4]);
        assert!(!s.exempt[5], "span ends at the closing brace");
    }

    #[test]
    fn braceless_cfg_test_item_does_not_leak() {
        let src = "\
#[cfg(test)]
use crate::something;
fn live() {}
";
        let s = strip(src);
        assert!(s.exempt[0] && s.exempt[1]);
        assert!(!s.exempt[2], "the `;` ends the attribute's reach");
    }

    #[test]
    fn braces_inside_strings_do_not_desync_spans() {
        let src = "\
#[cfg(test)]
mod tests {
    const S: &str = \"}}}{{{\";
}
fn live() {}
";
        let s = strip(src);
        assert!(s.exempt[2] && s.exempt[3]);
        assert!(!s.exempt[4]);
    }
}
