//! Crate-wide error type.
//!
//! Hand-rolled `Display`/`Error` impls (no `thiserror`): the offline build
//! policy keeps this crate free of crates.io dependencies.

use std::fmt;

#[derive(Debug)]
pub enum Error {
    Xla(xla::Error),
    Io(std::io::Error),
    Manifest(String),
    ShapeMismatch {
        name: String,
        expected: Vec<usize>,
        got: Vec<usize>,
    },
    UnknownExecutable(String),
    Config(String),
    Plan(String),
    Schedule(String),
    Cluster(String),
    Scenario(String),
    Lint(String),
    Other(String),
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        match self {
            Error::Xla(e) => write!(f, "xla error: {e}"),
            Error::Io(e) => write!(f, "io error: {e}"),
            Error::Manifest(msg) => write!(f, "manifest error: {msg}"),
            Error::ShapeMismatch { name, expected, got } => write!(
                f,
                "shape mismatch for `{name}`: expected {expected:?}, got {got:?}"
            ),
            Error::UnknownExecutable(name) => {
                write!(f, "unknown executable `{name}` (not in manifest)")
            }
            Error::Config(msg) => write!(f, "config error: {msg}"),
            Error::Plan(msg) => write!(f, "plan error: {msg}"),
            Error::Schedule(msg) => write!(f, "schedule error: {msg}"),
            Error::Cluster(msg) => write!(f, "cluster error: {msg}"),
            Error::Scenario(msg) => write!(f, "scenario error: {msg}"),
            Error::Lint(msg) => write!(f, "lint error: {msg}"),
            Error::Other(msg) => write!(f, "{msg}"),
        }
    }
}

impl std::error::Error for Error {
    fn source(&self) -> Option<&(dyn std::error::Error + 'static)> {
        match self {
            Error::Xla(e) => Some(e),
            Error::Io(e) => Some(e),
            _ => None,
        }
    }
}

impl From<xla::Error> for Error {
    fn from(e: xla::Error) -> Self {
        Error::Xla(e)
    }
}

impl From<std::io::Error> for Error {
    fn from(e: std::io::Error) -> Self {
        Error::Io(e)
    }
}

pub type Result<T> = std::result::Result<T, Error>;

impl Error {
    pub fn other(msg: impl Into<String>) -> Self {
        Error::Other(msg.into())
    }
}
