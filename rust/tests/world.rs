//! World-model acceptance battery: the degenerate (no-event) world is
//! byte-invisible, the committed `ringada_world` v1 fixture replays
//! seed-deterministically across policies and seeds, trace loading is
//! equivalent to embedding the world in the config, and checkpoints
//! taken mid-world-event (between a join and its outage, after an energy
//! exhaustion, ...) restore byte-identically.

use ringada::config::{AdmissionControl, FleetConfig};
use ringada::fleet::{
    serve, serve_reference, serve_streaming, AllocationPolicy, DeadlineEdf, FifoWholeRing,
    FleetState, SmallestRingFirst, UtilizationAware,
};
use ringada::sim::Scenario;
use ringada::util::json::Json;
use ringada::world::{World, WorldEvent, WORLD_TRACE_VERSION};

fn policies() -> [&'static dyn AllocationPolicy; 4] {
    [&FifoWholeRing, &SmallestRingFirst, &UtilizationAware, &DeadlineEdf]
}

/// The committed mini world trace: a correlated domain outage over
/// devices {1, 2}, two joins, and a battery so small device 0 exhausts
/// at its first round boundary.
fn fixture_path() -> String {
    concat!(env!("CARGO_MANIFEST_DIR"), "/tests/data/world_mini.jsonl").to_string()
}

fn world_cfg(seed: u64, jobs: usize) -> FleetConfig {
    let mut cfg = FleetConfig::synthetic(8, jobs, seed);
    cfg.mean_interarrival_s = 30.0;
    cfg.world_trace_path = Some(fixture_path());
    cfg
}

// ------------------------------------------------------- degenerate world

#[test]
fn empty_world_is_byte_invisible_healthy_and_faulted() {
    // A configured world with no events must not change a single byte of
    // any trajectory — the pre-world golden batteries keep their meaning.
    for seed in [3u64, 9] {
        let mut plain = FleetConfig::synthetic(12, 8, seed);
        plain.mean_interarrival_s = 12.0;
        let mut faulted = plain.clone();
        faulted.scenario = Some(Scenario::synth(seed, 12, 2000.0, 0.8));
        for base in [&plain, &faulted] {
            let mut with_empty = base.clone();
            with_empty.world = Some(World::empty());
            for policy in policies() {
                let a = serve(base, policy).unwrap();
                let b = serve(&with_empty, policy).unwrap();
                assert_eq!(
                    a.canonical_string(),
                    b.canonical_string(),
                    "empty world changed the run (seed {seed}, policy {})",
                    policy.name()
                );
                assert!(b.world.is_none(), "empty world must resolve to no world");
            }
        }
        // Streaming agrees too.
        let (a, _) = serve_streaming(&plain, &FifoWholeRing).unwrap();
        let mut with_empty = plain.clone();
        with_empty.world = Some(World::empty());
        let (b, _) = serve_streaming(&with_empty, &FifoWholeRing).unwrap();
        assert_eq!(a.to_json().to_string(), b.to_json().to_string());
    }
}

#[test]
fn serve_reference_refuses_world_configs() {
    // The legacy differential path cannot express pool churn; it must
    // refuse rather than silently ignore the world (even a degenerate
    // one — the guard is on the config, not the resolved timeline).
    let mut cfg = FleetConfig::synthetic(8, 4, 3);
    cfg.world = Some(World::empty());
    assert!(serve_reference(&cfg, &FifoWholeRing).is_err());
    let mut cfg = FleetConfig::synthetic(8, 4, 3);
    cfg.world_trace_path = Some(fixture_path());
    assert!(serve_reference(&cfg, &FifoWholeRing).is_err());
}

// ------------------------------------------------------ fixture conformance

#[test]
fn fixture_trace_round_trips_byte_identically() {
    // The CI conformance check: this build's canonical JSONL form of the
    // committed fixture is the committed bytes themselves.
    let text = std::fs::read_to_string(fixture_path()).unwrap();
    let world = World::from_jsonl(&text).unwrap();
    assert_eq!(world.to_jsonl(), text, "ringada_world v1 canonical form drifted");
    assert_eq!(WORLD_TRACE_VERSION, 1);
    assert_eq!(world.name, "mini-world");
    assert_eq!(world.join_count(), 2);
    let outages = world
        .events
        .iter()
        .filter(|e| matches!(e, WorldEvent::DomainOutage { .. }))
        .count();
    assert_eq!(outages, 1);
    let budgets = world
        .events
        .iter()
        .filter(|e| matches!(e, WorldEvent::EnergyBudget { .. }))
        .count();
    assert_eq!(budgets, 1);
    // Loading through the config path yields the same world.
    let cfg = world_cfg(3, 4);
    assert_eq!(cfg.resolve_world().unwrap().unwrap(), world);
}

#[test]
fn trace_path_and_embedded_world_serve_identically() {
    let by_path = world_cfg(5, 8);
    let mut embedded = by_path.clone();
    embedded.world_trace_path = None;
    embedded.world = Some(World::load(&fixture_path()).unwrap());
    let a = serve(&by_path, &FifoWholeRing).unwrap();
    let b = serve(&embedded, &FifoWholeRing).unwrap();
    assert_eq!(a.canonical_string(), b.canonical_string());
}

// --------------------------------------------------------- fixture goldens

#[test]
fn fixture_world_is_seed_deterministic_for_every_policy() {
    // The acceptance battery: the fixture (outage + joins + exhaustion)
    // produces byte-identical replays across >= 2 policies x >= 2 seeds,
    // with the world section pinning the same availability story.
    for seed in [5u64, 9] {
        for policy in policies() {
            let cfg = world_cfg(seed, 12);
            let a = serve(&cfg, policy).unwrap();
            let b = serve(&cfg, policy).unwrap();
            assert_eq!(
                a.canonical_string(),
                b.canonical_string(),
                "world run not deterministic (seed {seed}, policy {})",
                policy.name()
            );
            assert_eq!(
                a.completed() + a.failed_jobs() + a.unserved(),
                cfg.jobs,
                "job conservation violated (seed {seed}, policy {})",
                policy.name()
            );
            // The pool grew by the two joins.
            assert_eq!(a.pool_devices, 10);
            assert_eq!(a.pool_device_busy.len(), 10);
            let w = a.world.as_ref().expect("world run must report world stats");
            assert_eq!(w.base_devices, 8);
            assert_eq!(w.joins, 2);
            assert_eq!(w.outages, 1);
            // The rack-a outage always lands (both members lost); the
            // joined rack-b device survives.
            assert_eq!(
                w.domains,
                vec![("rack-a".to_string(), 2, 2), ("rack-b".to_string(), 1, 0)]
            );
            // Every death is either the outage or battery exhaustion.
            assert_eq!(a.dead_devices, 2 + w.energy_exhausted);
        }
    }
}

#[test]
fn fifo_fixture_run_exhausts_the_budgeted_device() {
    // FIFO's first grant is the free-pool prefix, so device 0 (2 J at
    // 1 W: two active seconds) always burns out at a round boundary.
    for seed in [5u64, 9] {
        let report = serve(&world_cfg(seed, 12), &FifoWholeRing).unwrap();
        let w = report.world.as_ref().unwrap();
        assert_eq!(w.energy_exhausted, 1, "seed {seed}");
        assert_eq!(w.energy_spent_j, 2.0, "the drained battery reports its capacity");
        assert_eq!(report.dead_devices, 3, "outage pair + exhausted device");
        // Losing ring members mid-flight forces at least one re-plan.
        let replans: usize = report.rows.iter().map(|r| r.replans).sum();
        assert!(replans >= 1, "seed {seed}: no job ever re-planned");
    }
}

// ----------------------------------------------------- checkpoint/restore

/// Run `k` events, snapshot, round-trip the snapshot through text,
/// resume into a fresh state, finish, and return the canonical string.
fn killed_at(cfg: &FleetConfig, policy: &dyn AllocationPolicy, k: usize) -> String {
    let mut state = FleetState::new(cfg, policy).unwrap();
    for i in 0..k {
        assert!(state.step_event().unwrap(), "event stream ended early at {i}/{k}");
    }
    let text = state.snapshot().unwrap().to_string();
    drop(state);
    let reparsed = Json::parse(&text).unwrap();
    let mut resumed = FleetState::resume(cfg, policy, &reparsed).unwrap();
    resumed.run_to_end().unwrap();
    resumed.into_report().unwrap().canonical_string()
}

#[test]
fn kill_at_every_event_replays_the_fixture_world_byte_identically() {
    // PR 6 compatibility acceptance: snapshots taken at *every* event —
    // including between the two join dispatches, mid-outage-aftermath,
    // and after the energy exhaustion — restore and finish on the exact
    // bytes of the uninterrupted run.
    let cfg = world_cfg(7, 8);
    for policy in [&FifoWholeRing as &dyn AllocationPolicy, &DeadlineEdf] {
        let want = serve(&cfg, policy).unwrap().canonical_string();
        let mut counter = FleetState::new(&cfg, policy).unwrap();
        let mut total = 0usize;
        while counter.step_event().unwrap() {
            total += 1;
        }
        assert!(total > 20, "battery config too small: only {total} events");
        for k in 0..=total {
            assert_eq!(
                killed_at(&cfg, policy, k),
                want,
                "kill at event {k}/{total} diverged (policy {})",
                policy.name()
            );
        }
    }
}

#[test]
fn world_snapshots_restore_under_preemption_and_admission() {
    let mut cfg = world_cfg(11, 8);
    cfg.preemption = true;
    cfg.admission = AdmissionControl::Feasibility;
    let want = serve(&cfg, &DeadlineEdf).unwrap().canonical_string();
    let mut counter = FleetState::new(&cfg, &DeadlineEdf).unwrap();
    let mut total = 0usize;
    while counter.step_event().unwrap() {
        total += 1;
    }
    for k in (0..=total).step_by(7) {
        assert_eq!(killed_at(&cfg, &DeadlineEdf, k), want, "kill at {k}/{total} diverged");
    }
    assert_eq!(killed_at(&cfg, &DeadlineEdf, total), want);
}

#[test]
fn world_snapshot_rejects_mismatched_configs() {
    // A snapshot taken with a world cannot restore into a world-less
    // config (and vice versa): the ledgers would silently desynchronize.
    let cfg = world_cfg(3, 6);
    let mut state = FleetState::new(&cfg, &FifoWholeRing).unwrap();
    for _ in 0..5 {
        assert!(state.step_event().unwrap());
    }
    let text = state.snapshot().unwrap().to_string();
    let snap = Json::parse(&text).unwrap();
    let mut worldless = cfg.clone();
    worldless.world_trace_path = None;
    assert!(FleetState::resume(&worldless, &FifoWholeRing, &snap).is_err());

    let mut plain = FleetConfig::synthetic(8, 6, 3);
    plain.mean_interarrival_s = 30.0;
    let mut state = FleetState::new(&plain, &FifoWholeRing).unwrap();
    for _ in 0..5 {
        assert!(state.step_event().unwrap());
    }
    let plain_snap = Json::parse(&state.snapshot().unwrap().to_string()).unwrap();
    let mut worldly = plain.clone();
    worldly.world_trace_path = Some(fixture_path());
    assert!(FleetState::resume(&worldly, &FifoWholeRing, &plain_snap).is_err());
}

// ------------------------------------------------------------- streaming

#[test]
fn streaming_world_runs_agree_with_the_materialized_report() {
    // The bounded-memory path shares the event loop: device accounting
    // (including joined devices and world deaths) matches bitwise.
    let cfg = world_cfg(5, 12);
    let report = serve(&cfg, &FifoWholeRing).unwrap();
    let (agg, _) = serve_streaming(&cfg, &FifoWholeRing).unwrap();
    assert_eq!(agg.jobs, report.rows.len());
    assert_eq!(agg.dead_devices, report.dead_devices);
    assert_eq!(agg.horizon_s.to_bits(), report.horizon_s.to_bits());
    let busy: f64 = report.pool_device_busy.iter().sum();
    assert_eq!(agg.pool_busy_s.to_bits(), busy.to_bits());
    // And replays identically.
    let (again, _) = serve_streaming(&cfg, &FifoWholeRing).unwrap();
    assert_eq!(agg.to_json().to_string(), again.to_json().to_string());
}
