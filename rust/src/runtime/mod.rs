//! L3 runtime: PJRT execution of the AOT artifacts plus everything the
//! coordinator needs around it (tensors, weights, optimizers, RNG).
//!
//! Layering (DESIGN.md §3): python/jax lowers the model ONCE at build time
//! (`make artifacts`); this module loads the HLO text and executes it —
//! python never runs on the training path.

pub mod device_weights;
pub mod engine;
pub mod optim;
pub mod rng;
pub mod stage;
pub mod tensor;
pub mod weights;

pub use device_weights::DeviceWeights;
pub use engine::{Engine, ExecStats};
pub use optim::{Adam, Sgd};
pub use rng::Rng;
pub use stage::StageRunner;
pub use tensor::{HostTensor, TensorData};
pub use weights::ModelWeights;

/// Whether a real PJRT runtime is linked.  The offline build ships the
/// in-tree `xla` shim (compilation/execution stubbed), so artifact-driven
/// tests and benches must gate on this *and* artifact presence.
pub fn pjrt_available() -> bool {
    !xla::STUBBED_RUNTIME
}
