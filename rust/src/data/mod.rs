//! Synthetic extractive-QA corpus — the SQuAD stand-in (DESIGN.md §2).
//!
//! Task: the input is `[CLS] <question tokens> [SEP] <context tokens>`;
//! the context contains exactly one *highlighted* span `[HLS] <answer
//! tokens> [HLE]` (the answer repeats the question tokens, SQuAD-style),
//! and the label is the `(start, end)` position of the highlighted span
//! (markers inclusive).
//!
//! Why markers: the paper fine-tunes a *pretrained* mBERT, whose attention
//! can do content-based question→context matching out of the box.  Our
//! backbone is synthesized (frozen random — DESIGN.md §2), and serial
//! adapters are per-token MLPs: they cannot create the cross-token
//! matching a pure copy-task needs.  Boundary markers keep the task
//! extractive-QA-shaped (find the answer span; F1/EM metrics unchanged)
//! while making it learnable in the frozen-backbone + adapter regime —
//! token identity survives the residual stream, so span detection is
//! exactly what adapters + head can and must learn.
//!
//! Each device draws from its own token sub-range (plus a shared pool) so
//! the per-device datasets are non-iid: using *all* devices' data — the
//! paper's data-efficiency argument — measurably helps.

use crate::error::{Error, Result};
use crate::runtime::rng::Rng;
use crate::runtime::tensor::HostTensor;

/// Reserved token ids.
pub const PAD: i32 = 0;
pub const CLS: i32 = 1;
pub const SEP: i32 = 2;
/// Highlight-start marker: opens the answer span.
pub const HLS: i32 = 3;
/// Highlight-end marker: closes the answer span.
pub const HLE: i32 = 4;
pub const FIRST_CONTENT: i32 = 5;

/// One QA example.
#[derive(Debug, Clone, PartialEq)]
pub struct Example {
    /// Token ids, length = seq.
    pub ids: Vec<i32>,
    /// Answer span, inclusive positions into `ids`.
    pub start: i32,
    pub end: i32,
}

/// A batch matching the artifact shapes: `ids s32[B,S]`, labels `s32[B]`.
#[derive(Debug, Clone)]
pub struct Batch {
    pub ids: HostTensor,
    pub starts: HostTensor,
    pub ends: HostTensor,
    pub size: usize,
}

/// Generator parameters.
#[derive(Debug, Clone)]
pub struct QaConfig {
    pub vocab: usize,
    pub seq: usize,
    /// Question length range (inclusive).
    pub q_min: usize,
    pub q_max: usize,
}

impl QaConfig {
    pub fn for_model(vocab: usize, seq: usize) -> Self {
        // Keep questions short relative to seq so spans fit comfortably.
        let q_max = (seq / 8).clamp(2, 6).min(seq.saturating_sub(8) / 2).max(2);
        QaConfig { vocab, seq, q_min: 2, q_max }
    }
}

/// Synthetic QA dataset for one device.
#[derive(Debug, Clone)]
pub struct SyntheticQa {
    pub cfg: QaConfig,
    pub examples: Vec<Example>,
}

impl SyntheticQa {
    /// Generate `n` examples for `device` (seeded).  Devices share the seed
    /// base but fork distinct streams, and each device's *context* tokens
    /// are biased towards a device-specific third of the vocabulary.
    pub fn generate(cfg: &QaConfig, device: usize, n: usize, seed: u64) -> Result<Self> {
        if cfg.vocab < (FIRST_CONTENT as usize) + 8 {
            return Err(Error::Config("vocab too small for QA generation".into()));
        }
        if cfg.seq < cfg.q_max * 2 + 4 {
            return Err(Error::Config(format!(
                "seq {} too short for q_max {}",
                cfg.seq, cfg.q_max
            )));
        }
        let mut rng = Rng::new(seed).fork(0xDA7A + device as u64);
        let examples = (0..n).map(|_| gen_example(cfg, device, &mut rng)).collect();
        Ok(SyntheticQa { cfg: cfg.clone(), examples })
    }

    pub fn len(&self) -> usize {
        self.examples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.examples.is_empty()
    }

    /// Sample a batch of `batch` examples (with replacement — the
    /// mini-batch sampling of Algorithm 1 line 7).
    pub fn sample_batch(&self, batch: usize, rng: &mut Rng) -> Result<Batch> {
        let picks: Vec<&Example> = (0..batch)
            .map(|_| &self.examples[rng.next_below(self.examples.len())])
            .collect();
        batch_from(&picks, self.cfg.seq)
    }

    /// Deterministic batches covering the dataset (for evaluation); the
    /// final ragged batch is padded by repeating the last example (the
    /// padding is excluded from scoring via the returned real count).
    pub fn eval_batches(&self, batch: usize) -> Result<Vec<(Batch, usize)>> {
        let mut out = Vec::new();
        let mut i = 0;
        while i < self.examples.len() {
            let real = (self.examples.len() - i).min(batch);
            let mut picks: Vec<&Example> =
                self.examples[i..i + real].iter().collect();
            while picks.len() < batch {
                picks.push(picks[real - 1]);
            }
            out.push((batch_from(&picks, self.cfg.seq)?, real));
            i += real;
        }
        Ok(out)
    }
}

fn gen_example(cfg: &QaConfig, device: usize, rng: &mut Rng) -> Example {
    let content = cfg.vocab as i32 - FIRST_CONTENT;
    // Device-specific bias: 2/3 of context tokens come from the device's
    // own third of the content vocab.
    let third = (content / 3).max(1);
    let dev_lo = FIRST_CONTENT + (device as i32 % 3) * third;

    let qlen = cfg.q_min + rng.next_below(cfg.q_max - cfg.q_min + 1);
    let question: Vec<i32> = (0..qlen)
        .map(|_| FIRST_CONTENT + rng.next_below(content as usize) as i32)
        .collect();

    let mut ids = Vec::with_capacity(cfg.seq);
    ids.push(CLS);
    ids.extend(&question);
    ids.push(SEP);

    let ctx_start = ids.len();
    let ctx_len = cfg.seq - ctx_start;
    for _ in 0..ctx_len {
        let t = if rng.next_f64() < 0.67 {
            dev_lo + rng.next_below(third as usize) as i32
        } else {
            FIRST_CONTENT + rng.next_below(content as usize) as i32
        };
        ids.push(t);
    }

    // Plant the highlighted answer: `[HLS] <question copy> [HLE]` at a
    // random context position.  Content tokens never collide with the
    // markers (they start at FIRST_CONTENT), so the span is unique by
    // construction.
    let span_len = qlen + 2;
    let plant_at = ctx_start + rng.next_below(ctx_len - span_len + 1);
    ids[plant_at] = HLS;
    ids[plant_at + 1..plant_at + 1 + qlen].copy_from_slice(&question);
    ids[plant_at + span_len - 1] = HLE;

    Example {
        ids,
        start: plant_at as i32,
        end: (plant_at + span_len - 1) as i32,
    }
}

fn batch_from(picks: &[&Example], seq: usize) -> Result<Batch> {
    let b = picks.len();
    let mut ids = Vec::with_capacity(b * seq);
    let mut starts = Vec::with_capacity(b);
    let mut ends = Vec::with_capacity(b);
    for ex in picks {
        ids.extend(&ex.ids);
        starts.push(ex.start);
        ends.push(ex.end);
    }
    Ok(Batch {
        ids: HostTensor::i32(vec![b, seq], ids)?,
        starts: HostTensor::i32(vec![b], starts)?,
        ends: HostTensor::i32(vec![b], ends)?,
        size: b,
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    fn cfg() -> QaConfig {
        QaConfig::for_model(512, 32)
    }

    #[test]
    fn examples_have_valid_structure() {
        let ds = SyntheticQa::generate(&cfg(), 0, 64, 1).unwrap();
        for ex in &ds.examples {
            assert_eq!(ex.ids.len(), 32);
            assert_eq!(ex.ids[0], CLS);
            assert!(ex.start < ex.end);
            assert!((ex.end as usize) < 32);
            // Span = [HLS] <question copy> [HLE].
            let sep = ex.ids.iter().position(|&t| t == SEP).unwrap();
            let question = &ex.ids[1..sep];
            let span = &ex.ids[ex.start as usize..=ex.end as usize];
            assert_eq!(span[0], HLS);
            assert_eq!(*span.last().unwrap(), HLE);
            assert_eq!(&span[1..span.len() - 1], question);
            // Span lies inside the context (after SEP).
            assert!(ex.start as usize > sep);
        }
    }

    #[test]
    fn answer_span_is_unique() {
        // Exactly one highlight per example (markers are reserved ids).
        let ds = SyntheticQa::generate(&cfg(), 1, 128, 2).unwrap();
        for ex in &ds.examples {
            assert_eq!(ex.ids.iter().filter(|&&t| t == HLS).count(), 1);
            assert_eq!(ex.ids.iter().filter(|&&t| t == HLE).count(), 1);
            assert_eq!(ex.ids[ex.start as usize], HLS);
            assert_eq!(ex.ids[ex.end as usize], HLE);
        }
    }

    #[test]
    fn generation_is_deterministic_per_device() {
        let a = SyntheticQa::generate(&cfg(), 0, 16, 7).unwrap();
        let b = SyntheticQa::generate(&cfg(), 0, 16, 7).unwrap();
        assert_eq!(a.examples, b.examples);
        let c = SyntheticQa::generate(&cfg(), 1, 16, 7).unwrap();
        assert_ne!(a.examples, c.examples);
    }

    #[test]
    fn batches_have_artifact_shapes() {
        let ds = SyntheticQa::generate(&cfg(), 0, 16, 7).unwrap();
        let mut rng = Rng::new(0);
        let b = ds.sample_batch(4, &mut rng).unwrap();
        assert_eq!(b.ids.shape, vec![4, 32]);
        assert_eq!(b.starts.shape, vec![4]);
        assert_eq!(b.ends.shape, vec![4]);
    }

    #[test]
    fn eval_batches_cover_dataset_with_padding() {
        let ds = SyntheticQa::generate(&cfg(), 0, 10, 7).unwrap();
        let batches = ds.eval_batches(4).unwrap();
        assert_eq!(batches.len(), 3);
        assert_eq!(batches[0].1, 4);
        assert_eq!(batches[2].1, 2); // 10 = 4 + 4 + 2
        assert_eq!(batches[2].0.ids.shape, vec![4, 32]); // padded to full batch
    }

    #[test]
    fn rejects_too_small_shapes() {
        let bad = QaConfig { vocab: 4, seq: 32, q_min: 2, q_max: 4 };
        assert!(SyntheticQa::generate(&bad, 0, 4, 1).is_err());
        let bad2 = QaConfig { vocab: 512, seq: 8, q_min: 2, q_max: 6 };
        assert!(SyntheticQa::generate(&bad2, 0, 4, 1).is_err());
    }

    #[test]
    fn pad_token_is_reserved() {
        // No generated example should ever contain PAD (all positions are
        // meaningful in this fixed-length task).
        let ds = SyntheticQa::generate(&cfg(), 2, 32, 3).unwrap();
        assert!(ds.examples.iter().all(|e| e.ids.iter().all(|&t| t != PAD)));
    }
}
