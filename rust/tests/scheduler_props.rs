//! Property battery over the scheduler core, covering all three schemes
//! (DESIGN goals restated by ISSUE 1): DAG acyclicity under arbitrary
//! step/handoff mixes, backward early-stop never reaching below the
//! terminator, and the RingAda pause rule yielding exactly one weight
//! version per ring position and step.
//!
//! Complements `coordinator_invariants.rs` (which pins the RingAda-only
//! invariants); here every property is driven across `Scheme::ALL` with
//! randomized cluster sizes, block counts, unfreeze depths and rounds.

use ringada::config::{ClusterConfig, Scheme, TrainingConfig};
use ringada::coordinator::{Coordinator, LayerAssignment};
use ringada::model::manifest::ModelHyper;
use ringada::model::ModelMeta;
use ringada::pipeline::{invariants, validate_dag, Kind, Op, ScheduleBuilder, WireSizes};
use ringada::prop_check;
use ringada::runtime::Rng;
use ringada::util::prop::forall;

fn meta(layers: usize) -> ModelMeta {
    ModelMeta::from_hyper(ModelHyper {
        name: "props".into(),
        vocab: 256,
        hidden: 32,
        layers,
        heads: 4,
        ffn: 64,
        bottleneck: 8,
        seq: 16,
        batch: 2,
        init_std: 0.02,
    })
}

fn random_assignment(rng: &mut Rng, devices: usize, layers: usize) -> LayerAssignment {
    let mut counts = vec![1usize; devices];
    for _ in 0..layers - devices {
        counts[rng.next_below(devices)] += 1;
    }
    let mut order: Vec<usize> = (0..devices).collect();
    rng.shuffle(&mut order);
    LayerAssignment::from_counts(order, &counts).unwrap()
}

fn random_setup(rng: &mut Rng) -> (Coordinator, usize, usize) {
    let devices = 2 + rng.next_below(5); // 2..=6
    let layers = devices + rng.next_below(12);
    let assignment = random_assignment(rng, devices, layers);
    let training = TrainingConfig {
        initial_depth: 1 + rng.next_below(layers),
        unfreeze_interval: 1 + rng.next_below(20),
        ..Default::default()
    };
    let c = Coordinator::with_assignment(
        assignment,
        &meta(layers),
        &ClusterConfig::homogeneous(devices, 1e7),
        &training,
    )
    .unwrap();
    (c, devices, layers)
}

fn sizes() -> WireSizes {
    WireSizes { activation_bytes: 1024, head_bytes: 64 }
}

fn random_scheme(rng: &mut Rng) -> Scheme {
    Scheme::ALL[rng.next_below(3)]
}

/// Emit `steps` steps of `scheme` (rotating initiators, with handoffs for
/// the ring schemes) and return the DAG.
fn build_steps(
    c: &Coordinator,
    scheme: Scheme,
    devices: usize,
    layers: usize,
    steps: usize,
    round: usize,
) -> Result<Vec<ringada::pipeline::Task>, String> {
    let rp = c.round_plan(round).map_err(|e| e.to_string())?;
    let mut b = ScheduleBuilder::new(c.assignment.clone(), sizes(), devices);
    let mut prev_initiator: Option<usize> = None;
    for s in 0..steps {
        let initiator = rp.initiators[s % devices];
        if scheme != Scheme::Single {
            if let Some(p) = prev_initiator.filter(|&p| p != initiator) {
                b.head_handoff(p, initiator, round).map_err(|e| e.to_string())?;
            }
        }
        match scheme {
            Scheme::RingAda => b.ringada_step(&rp, initiator),
            Scheme::PipeAdapter => b.pipe_adapter_step(&rp, initiator),
            Scheme::Single => b.single_step(&rp, 0, layers),
        }
        .map_err(|e| e.to_string())?;
        prev_initiator = Some(initiator);
    }
    let (tasks, _) = b.into_tasks();
    Ok(tasks)
}

#[test]
fn prop_dag_is_acyclic_for_every_scheme_and_round() {
    forall(120, |rng| {
        let (c, devices, layers) = random_setup(rng);
        let scheme = random_scheme(rng);
        let round = rng.next_below(80);
        let steps = 1 + rng.next_below(5);
        let tasks = build_steps(&c, scheme, devices, layers, steps, round)?;
        validate_dag(&tasks).map_err(|e| e.to_string())?;
        // Dense ids in emission order = topological by construction; also
        // every dep must resolve inside the chunk.
        for t in &tasks {
            for &d in &t.deps {
                prop_check!(d < t.id, "task {} deps on later {d}", t.id);
            }
        }
        Ok(())
    });
}

#[test]
fn prop_backward_early_stop_never_emits_below_terminator() {
    forall(120, |rng| {
        let (c, devices, layers) = random_setup(rng);
        let scheme = random_scheme(rng);
        let round = rng.next_below(80);
        let rp = c.round_plan(round).map_err(|e| e.to_string())?;
        let tasks = build_steps(&c, scheme, devices, layers, 2, round)?;

        // Per-step backward block count: early-stopped depth for RingAda,
        // full model depth for both baselines.
        let per_step = invariants::bwd_blocks_per_step(&tasks);
        let want = match scheme {
            Scheme::RingAda => rp.depth,
            _ => layers,
        };
        for step in 0..2 {
            let got = per_step.get(&step).copied().unwrap_or(0);
            prop_check!(
                got == want,
                "step {step}: bwd blocks {got} != {want} ({scheme:?}, depth {}, layers {layers})",
                rp.depth
            );
        }

        // No backward compute may land on a ring position strictly below
        // the terminator position (RingAda only; baselines walk the full
        // ring by design).
        if scheme == Scheme::RingAda {
            for t in &tasks {
                if let Kind::Compute { device, op: Op::BlockBwd { .. } } = t.kind {
                    let pos = c.assignment.position_of_device(device).map_err(|e| e.to_string())?;
                    prop_check!(
                        pos >= rp.terminator_position,
                        "bwd on position {pos} below terminator {}",
                        rp.terminator_position
                    );
                }
            }
        }
        Ok(())
    });
}

#[test]
fn prop_pause_rule_yields_exactly_one_weight_version_per_position() {
    forall(120, |rng| {
        let (c, devices, layers) = random_setup(rng);
        let round = rng.next_below(80);
        let rp = c.round_plan(round).map_err(|e| e.to_string())?;
        let steps = 2 + rng.next_below(3);
        let tasks = build_steps(&c, Scheme::RingAda, devices, layers, steps, round)?;

        let unfrozen = c.assignment.unfrozen_per_position(rp.terminator_block);
        for pos in 0..devices {
            let dev = c.assignment.order[pos];
            // Exactly one AdapterUpdate per step on unfrozen positions;
            // zero anywhere frozen — this is the "single weight version per
            // position" guarantee in DAG form.
            for step in 0..steps {
                let updates = tasks
                    .iter()
                    .filter(|t| {
                        t.step == step
                            && matches!(
                                t.kind,
                                Kind::Compute { device: d, op: Op::AdapterUpdate { .. } } if d == dev
                            )
                    })
                    .count();
                let want = usize::from(unfrozen[pos] > 0);
                prop_check!(
                    updates == want,
                    "position {pos} step {step}: {updates} updates, want {want}"
                );
            }
            // And every later forward on an unfrozen position must hold a
            // direct edge to that position's latest update (the pause rule).
            if unfrozen[pos] > 0 {
                prop_check!(
                    invariants::fwd_waits_for_update(&tasks, dev),
                    "unfrozen position {pos} (device {dev}) missing a pause edge"
                );
            }
        }
        let _ = layers;
        Ok(())
    });
}

#[test]
fn prop_pipeadapter_never_pauses_but_single_never_leaves_its_device() {
    forall(100, |rng| {
        let (c, devices, layers) = random_setup(rng);
        let round = rng.next_below(40);

        // PipeAdapter: stale forwarding — no forward ever waits on an
        // adapter update (that is exactly what weight stashing buys).
        let pipe = build_steps(&c, Scheme::PipeAdapter, devices, layers, 3, round)?;
        for pos in 0..devices {
            let dev = c.assignment.order[pos];
            prop_check!(
                !invariants::fwd_waits_for_update(&pipe, dev),
                "PipeAdapter device {dev} has a pause edge"
            );
        }

        // Single: every compute lands on device 0, full-depth backward.
        let single = build_steps(&c, Scheme::Single, devices, layers, 2, round)?;
        prop_check!(
            single.iter().all(|t| matches!(t.kind, Kind::Compute { device: 0, .. })),
            "Single emitted off-device or transfer tasks"
        );
        Ok(())
    });
}

#[test]
fn prop_round_plan_depth_and_terminator_agree_with_assignment() {
    forall(150, |rng| {
        let (c, devices, layers) = random_setup(rng);
        let round = rng.next_below(200);
        let rp = c.round_plan(round).map_err(|e| e.to_string())?;
        prop_check!(
            rp.terminator_block == layers - rp.depth,
            "terminator {} != layers {layers} - depth {}",
            rp.terminator_block,
            rp.depth
        );
        let unfrozen = c.assignment.unfrozen_per_position(rp.terminator_block);
        let total: usize = unfrozen.iter().sum();
        prop_check!(total == rp.depth, "unfrozen total {total} != depth {}", rp.depth);
        // The terminator position is the first with any unfrozen block.
        for (pos, &u) in unfrozen.iter().enumerate() {
            if pos < rp.terminator_position {
                prop_check!(u == 0, "position {pos} below terminator has {u} unfrozen");
            }
        }
        prop_check!(
            unfrozen[rp.terminator_position] > 0,
            "terminator position {} is fully frozen",
            rp.terminator_position
        );
        let _ = devices;
        Ok(())
    });
}
