//! Deterministic fork-join executor (ISSUE 9, ROADMAP item 1).
//!
//! A zero-dependency scoped worker pool whose one contract is: **thread
//! count never changes results, only wall clock**.  Both entry points —
//! [`par_map`] over borrowed slices and [`par_map_owned`] over owned
//! items — collect results *index-ordered*, so a parallel map is
//! byte-identical to the sequential `iter().map()` it replaces.  With
//! `threads <= 1` (or a single item) the map short-circuits to the
//! exact sequential code path: same closure, same order, no threads
//! spawned at all.
//!
//! ## Determinism argument
//!
//! * Workers never share mutable state: each produces a private
//!   `(index, result)` vector; the fork-join parent concatenates the
//!   vectors and sorts by index.  The merged output is a pure function
//!   of `(items, f)` — scheduling order is unobservable.
//! * Work distribution itself may race (an atomic claim counter in
//!   [`par_map`], pre-computed strides in [`par_map_owned`]), but it
//!   only decides *which worker* computes an index, never *what* is
//!   computed for it — closures must be pure functions of
//!   `(index, item)`, which the planner/fleet call sites are.
//! * A panicking worker aborts the join and the panic is resumed on the
//!   caller's thread, exactly like the sequential path.
//!
//! The rest of the tree is kept honest by `ringada-lint` rule R6
//! (`parallel-primitives`): raw `thread::spawn`, `mpsc` channels, and
//! `Mutex`-accumulated results are forbidden outside this module, so
//! every parallel code path funnels through the ordered fork-join core.
//!
//! ## Thread-count resolution
//!
//! Call sites carry a validated `threads` knob (config key or
//! `SearchParams` field); [`resolve_threads`] applies the
//! `RINGADA_THREADS` environment override on top.  Precedence:
//! env var (when set and valid) > config value.  Zero is rejected in
//! both positions — "sequential" is spelled `threads = 1`.

use crate::error::{Error, Result};
use std::sync::atomic::{AtomicUsize, Ordering};

/// Environment override for the worker count; takes precedence over any
/// configured `threads` value when set.
pub const THREADS_ENV: &str = "RINGADA_THREADS";

/// Resolve the effective worker count from a validated config value and
/// the [`THREADS_ENV`] override.
///
/// * `requested == 0` is a config error ("sequential" is `1`);
/// * a set-but-invalid env var (empty, non-integer, or `0`) is an
///   error — a silently ignored override is worse than a loud one;
/// * an unset env var leaves the configured value in force.
pub fn resolve_threads(requested: usize) -> Result<usize> {
    if requested == 0 {
        return Err(Error::Config("threads must be >= 1 (use 1 for sequential)".into()));
    }
    match std::env::var(THREADS_ENV) {
        Ok(raw) => {
            let parsed = raw.trim().parse::<usize>().map_err(|_| {
                Error::Config(format!("{THREADS_ENV} must be a positive integer, got {raw:?}"))
            })?;
            if parsed == 0 {
                return Err(Error::Config(format!(
                    "{THREADS_ENV} must be >= 1 (use 1 for sequential), got 0"
                )));
            }
            Ok(parsed)
        }
        Err(std::env::VarError::NotPresent) => Ok(requested),
        Err(std::env::VarError::NotUnicode(_)) => {
            Err(Error::Config(format!("{THREADS_ENV} is not valid unicode")))
        }
    }
}

/// Map `f` over `items` on up to `threads` scoped workers, returning
/// results in item order.
///
/// `f` receives `(index, &item)` and must be a pure function of that
/// pair for the determinism contract to hold.  `threads <= 1` or
/// `items.len() <= 1` short-circuits to the sequential in-order loop.
/// Work is distributed by an atomic claim counter (idle workers steal
/// the next unclaimed index), so uneven item costs balance without any
/// effect on the merged output.
pub fn par_map<T, R, F>(threads: usize, items: &[T], f: F) -> Vec<R>
where
    T: Sync,
    R: Send,
    F: Fn(usize, &T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let workers = threads.min(items.len());
    let next = AtomicUsize::new(0);
    let mut tagged: Vec<(usize, R)> = Vec::with_capacity(items.len());
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for _ in 0..workers {
            handles.push(scope.spawn(|| {
                let mut local: Vec<(usize, R)> = Vec::new();
                loop {
                    let i = next.fetch_add(1, Ordering::Relaxed);
                    if i >= items.len() {
                        break;
                    }
                    local.push((i, f(i, &items[i])));
                }
                local
            }));
        }
        for h in handles {
            match h.join() {
                Ok(local) => tagged.extend(local),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    tagged.sort_by_key(|e| e.0);
    tagged.into_iter().map(|e| e.1).collect()
}

/// Map `f` over owned `items` on up to `threads` scoped workers,
/// returning results in item order.
///
/// The owned variant for non-`Sync` items (e.g. boxed job executors
/// moved out of the fleet run for a step batch): items are
/// pre-partitioned into per-worker stripes (`index % workers`) before
/// any thread spawns, so distribution is deterministic by construction.
/// `f` receives `(index, item)` by value.  `threads <= 1` or a single
/// item short-circuits to the sequential in-order loop.
pub fn par_map_owned<T, R, F>(threads: usize, items: Vec<T>, f: F) -> Vec<R>
where
    T: Send,
    R: Send,
    F: Fn(usize, T) -> R + Sync,
{
    if threads <= 1 || items.len() <= 1 {
        return items.into_iter().enumerate().map(|(i, it)| f(i, it)).collect();
    }
    let workers = threads.min(items.len());
    let mut lanes: Vec<Vec<(usize, T)>> = (0..workers).map(|_| Vec::new()).collect();
    for (i, item) in items.into_iter().enumerate() {
        lanes[i % workers].push((i, item));
    }
    let mut tagged: Vec<(usize, R)> = Vec::new();
    std::thread::scope(|scope| {
        let mut handles = Vec::with_capacity(workers);
        for lane in lanes {
            let fr = &f;
            handles.push(scope.spawn(move || {
                lane.into_iter().map(|(i, item)| (i, fr(i, item))).collect::<Vec<(usize, R)>>()
            }));
        }
        for h in handles {
            match h.join() {
                Ok(local) => tagged.extend(local),
                Err(payload) => std::panic::resume_unwind(payload),
            }
        }
    });
    tagged.sort_by_key(|e| e.0);
    tagged.into_iter().map(|e| e.1).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn par_map_matches_sequential_at_every_thread_count() {
        let items: Vec<u64> = (0..97).collect();
        let want: Vec<u64> = items.iter().enumerate().map(|(i, x)| x * 3 + i as u64).collect();
        for threads in [1, 2, 3, 4, 8, 128] {
            let got = par_map(threads, &items, |i, x| x * 3 + i as u64);
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn par_map_owned_matches_sequential_at_every_thread_count() {
        for threads in [1, 2, 3, 4, 8, 128] {
            let items: Vec<String> = (0..53).map(|i| format!("job{i}")).collect();
            let want: Vec<String> =
                items.iter().enumerate().map(|(i, s)| format!("{i}:{s}")).collect();
            let got = par_map_owned(threads, items, |i, s| format!("{i}:{s}"));
            assert_eq!(got, want, "threads={threads}");
        }
    }

    #[test]
    fn empty_and_singleton_inputs() {
        let empty: Vec<u32> = Vec::new();
        assert!(par_map(4, &empty, |_, x| *x).is_empty());
        assert!(par_map_owned(4, Vec::<u32>::new(), |_, x| x).is_empty());
        assert_eq!(par_map(4, &[7u32], |i, x| *x + i as u32), vec![7]);
        assert_eq!(par_map_owned(4, vec![7u32], |i, x| x + i as u32), vec![7]);
    }

    #[test]
    fn uneven_work_still_merges_in_order() {
        // Heavier items early: stealing reorders execution, never output.
        let items: Vec<usize> = (0..40).collect();
        let got = par_map(4, &items, |_, &x| {
            let mut acc = 0u64;
            for k in 0..(40 - x) * 1000 {
                acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k as u64);
            }
            ((x as u64) << 32) | (acc & 1)
        });
        let want = items
            .iter()
            .map(|&x| {
                let mut acc = 0u64;
                for k in 0..(40 - x) * 1000 {
                    acc = acc.wrapping_mul(6364136223846793005).wrapping_add(k as u64);
                }
                ((x as u64) << 32) | (acc & 1)
            })
            .collect::<Vec<_>>();
        assert_eq!(got, want);
    }

    #[test]
    fn resolve_threads_rejects_zero_request() {
        // Env-var cases are covered in `tests/exec_threads_env.rs`, whose
        // dedicated binary serializes the mutation behind one lock; here
        // only the pure-argument path.
        assert!(resolve_threads(0).is_err());
    }
}
