//! Regenerates the paper's **Fig. 3**: (a) training loss vs epochs and
//! (b) training loss vs wall-clock time for the three schemes, printing
//! down-sampled series and writing full CSVs under `results/`.
//!
//! Expected shape (paper §V): in (a) RingAda starts slower (partial
//! unfreezing) and the gap narrows; in (b) RingAda reaches any loss level
//! first, Single last.
//!
//! Run: `cargo bench --bench fig3`

use ringada::config::{ExperimentConfig, Scheme};
use ringada::train::{run_scheme_with, TrainOptions};

fn main() {
    if !ringada::runtime::pjrt_available() {
        eprintln!("skipping bench: PJRT is stubbed in this build (see rust/xla)");
        return;
    }
    let art = if std::path::Path::new("artifacts/small/manifest.json").exists() {
        "artifacts/small"
    } else if std::path::Path::new("artifacts/tiny/manifest.json").exists() {
        "artifacts/tiny"
    } else {
        eprintln!("skipping fig3 bench: artifacts missing (run `make artifacts`)");
        return;
    };
    eprintln!("fig3 bench on {art}");
    let mut exp = ExperimentConfig::paper_default(art);
    exp.training.rounds = 36; // the "800 epochs" axis, scaled down
    exp.training.local_iters = 2;
    exp.training.unfreeze_interval = 8;
    // Slow the descent so the curves are informative across the axis
    // (4e-3 converges within ~4 epochs on the synthetic task).
    exp.training.lr = 1.2e-3;
    exp.samples_per_device = 96;
    exp.eval_samples = 32;

    std::fs::create_dir_all("results").ok();
    let mut curves = Vec::new();
    for scheme in Scheme::ALL {
        let opts = TrainOptions { eval: false, verbose: false, loss_threshold: 0.5 };
        let r = run_scheme_with(&exp, scheme, &opts).expect("run");
        let path = format!("results/fig3_{}.csv", scheme.name().to_lowercase());
        r.curve.write_csv(&path).expect("csv");
        eprintln!("wrote {path}");
        curves.push((scheme, r.curve));
    }

    println!("\nFig. 3(a) — training loss vs epochs:");
    println!("{:>6} {:>12} {:>12} {:>12}", "epoch", "Single", "PipeAdapter", "RingAda");
    for i in (0..exp.training.rounds).step_by(4) {
        println!(
            "{:>6} {:>12.4} {:>12.4} {:>12.4}",
            i, curves[0].1.points[i].1, curves[1].1.points[i].1, curves[2].1.points[i].1
        );
    }

    println!("\nFig. 3(b) — training loss vs simulated time (s):");
    println!(
        "{:>6} {:>16} {:>16} {:>16}",
        "epoch", "Single t(loss)", "Pipe t(loss)", "RingAda t(loss)"
    );
    for i in (0..exp.training.rounds).step_by(4) {
        println!(
            "{:>6} {:>9.1}({:.3}) {:>9.1}({:.3}) {:>9.1}({:.3})",
            i,
            curves[0].1.sim_time_s[i],
            curves[0].1.points[i].1,
            curves[1].1.sim_time_s[i],
            curves[1].1.points[i].1,
            curves[2].1.sim_time_s[i],
            curves[2].1.points[i].1,
        );
    }

    // Shape checks — Fig. 3(b)'s claim is about reaching a loss level, so
    // compare simulated *time-to-threshold* (the Table I convergence
    // definition), not total time over a fixed round budget: RingAda's
    // advantage lives in the low-depth phase where convergence happens,
    // and the unfreeze schedule deepens (and slows) rounds afterwards.
    let thresh = 0.5;
    let t_single = curves[0].1.time_to_reach(thresh);
    let t_pipe = curves[1].1.time_to_reach(thresh);
    let t_ring = curves[2].1.time_to_reach(thresh);
    println!(
        "\ntime to loss {thresh}: Single {t_single:?}s, PipeAdapter {t_pipe:?}s, RingAda {t_ring:?}s"
    );
    match (t_single, t_pipe, t_ring) {
        (Some(s), Some(p), Some(r)) if r < p && p < s => {
            println!("shape: OK — RingAda < PipeAdapter < Single time-to-loss (paper Fig. 3(b))")
        }
        (Some(s), _, Some(r)) if r < s => {
            println!("shape: PARTIAL — RingAda beats Single; PipeAdapter ordering off")
        }
        _ => println!("shape: MISMATCH"),
    }
    // Early-epoch loss: RingAda should descend no faster than Single in (a).
    let early = 3.min(exp.training.rounds - 1);
    println!(
        "early-epoch loss (epoch {early}): Single {:.4} <= RingAda {:.4} expected (partial unfreezing)",
        curves[0].1.points[early].1, curves[2].1.points[early].1
    );
}
