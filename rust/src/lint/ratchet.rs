//! The `unwrap-ratchet` budget file (`lint_ratchet.json`).
//!
//! `.unwrap()` / `.expect(` calls in live library code are panic paths a
//! long-lived fleet service must not cross, but converting all of them at
//! once is not realistic — so the committed ratchet freezes today's
//! per-file counts and only lets them *fall*.  A count above its budget is
//! a gating finding (handle the error, or annotate the one provably-safe
//! call); a count below it is a *stale-ratchet* finding, fixed by running
//! `ringada-lint --update-ratchet` and committing the tightened file, so
//! the budget monotonically decreases over the repo's history.

use std::collections::BTreeMap;

use super::rules::{Finding, Rule};
use crate::error::{Error, Result};
use crate::util::json::Json;

/// Current on-disk format version.
pub const RATCHET_VERSION: u64 = 1;

/// Committed per-file `.unwrap()`/`.expect(` budgets.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct Ratchet {
    /// Display path (e.g. `src/fleet/mod.rs`) → budget.  Files with a
    /// zero budget are omitted.
    pub files: BTreeMap<String, usize>,
}

impl Ratchet {
    pub fn from_counts(counts: &BTreeMap<String, usize>) -> Ratchet {
        Ratchet {
            files: counts.iter().filter(|(_, &c)| c > 0).map(|(f, &c)| (f.clone(), c)).collect(),
        }
    }

    pub fn parse(text: &str) -> Result<Ratchet> {
        let v = Json::parse(text)?;
        let version = v.req("version")?.as_u64()?;
        if version != RATCHET_VERSION {
            return Err(Error::Lint(format!(
                "lint_ratchet.json version {version} (this binary understands {RATCHET_VERSION})"
            )));
        }
        let rule = v.req("rule")?.as_str()?;
        if rule != Rule::UnwrapRatchet.id() {
            return Err(Error::Lint(format!("lint_ratchet.json gates unknown rule `{rule}`")));
        }
        let mut files = BTreeMap::new();
        for (path, count) in v.req("files")?.as_obj()? {
            files.insert(path.clone(), count.as_usize()?);
        }
        Ok(Ratchet { files })
    }

    /// Serialized form; object keys are a `BTreeMap` underneath, so the
    /// output is byte-deterministic.
    pub fn to_json_string(&self) -> String {
        let files: BTreeMap<String, Json> =
            self.files.iter().map(|(f, &c)| (f.clone(), Json::u64(c as u64))).collect();
        Json::obj(vec![
            ("version", Json::u64(RATCHET_VERSION)),
            ("rule", Json::str(Rule::UnwrapRatchet.id())),
            ("files", Json::Obj(files)),
        ])
        .pretty()
    }

    /// Compare live counts against the budgets.  `lines` carries the
    /// 1-based source line of every live call per file, so an over-budget
    /// finding points at the first call *past* the budget.
    pub fn compare(&self, lines: &BTreeMap<String, Vec<usize>>) -> Vec<Finding> {
        let mut out = Vec::new();
        for (file, file_lines) in lines {
            let actual = file_lines.len();
            let budget = self.files.get(file).copied().unwrap_or(0);
            if actual > budget {
                let line = file_lines.get(budget).copied().unwrap_or(1);
                out.push(Finding {
                    file: file.clone(),
                    line,
                    rule: Rule::UnwrapRatchet,
                    message: format!(
                        "{actual} unwrap()/expect() calls exceed the ratchet budget of \
                         {budget}; convert the new call to a Result (or annotate the one \
                         provably-unreachable panic) — budgets never go up"
                    ),
                });
            } else if actual < budget {
                out.push(Finding {
                    file: file.clone(),
                    line: 1,
                    rule: Rule::UnwrapRatchet,
                    message: format!(
                        "ratchet is stale: {actual} live unwrap()/expect() calls against a \
                         budget of {budget}; run `ringada-lint --update-ratchet` and commit \
                         the tightened lint_ratchet.json"
                    ),
                });
            }
        }
        // Budgets for files that no longer exist (or now count zero) are
        // stale too.
        for (file, &budget) in &self.files {
            if budget > 0 && !lines.contains_key(file) {
                out.push(Finding {
                    file: file.clone(),
                    line: 1,
                    rule: Rule::UnwrapRatchet,
                    message: format!(
                        "ratchet is stale: file no longer exists (budget {budget}); run \
                         `ringada-lint --update-ratchet`"
                    ),
                });
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn lines(entries: &[(&str, &[usize])]) -> BTreeMap<String, Vec<usize>> {
        entries.iter().map(|(f, l)| (f.to_string(), l.to_vec())).collect()
    }

    fn ratchet(entries: &[(&str, usize)]) -> Ratchet {
        Ratchet {
            files: entries.iter().map(|(f, c)| (f.to_string(), *c)).collect(),
        }
    }

    #[test]
    fn equal_counts_pass() {
        let r = ratchet(&[("src/a.rs", 2)]);
        assert!(r.compare(&lines(&[("src/a.rs", &[10, 20])])).is_empty());
    }

    #[test]
    fn increase_fires_at_the_first_call_past_budget() {
        let r = ratchet(&[("src/a.rs", 2)]);
        let f = r.compare(&lines(&[("src/a.rs", &[10, 20, 30])]));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].rule, Rule::UnwrapRatchet);
        assert_eq!(f[0].line, 30, "points at the third call, the one over budget");
        // A file absent from the ratchet has budget zero.
        let f = r.compare(&lines(&[("src/a.rs", &[10, 20]), ("src/b.rs", &[5])]));
        assert_eq!(f.len(), 1);
        assert_eq!(f[0].file, "src/b.rs");
        assert_eq!(f[0].line, 5);
    }

    #[test]
    fn decrease_is_a_stale_ratchet_finding() {
        let r = ratchet(&[("src/a.rs", 3)]);
        let f = r.compare(&lines(&[("src/a.rs", &[10])]));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("stale"));
        // Deleted file with a leftover budget is stale too.
        let f = r.compare(&lines(&[]));
        assert_eq!(f.len(), 1);
        assert!(f[0].message.contains("no longer exists"));
    }

    #[test]
    fn json_round_trip_is_exact() {
        let r = ratchet(&[("src/a.rs", 2), ("src/z.rs", 7)]);
        let text = r.to_json_string();
        let back = Ratchet::parse(&text).expect("round trip");
        assert_eq!(r, back);
        // Zero-count files are dropped on construction from counts.
        let counts: BTreeMap<String, usize> =
            [("src/a.rs".to_string(), 0), ("src/b.rs".to_string(), 1)].into_iter().collect();
        let r = Ratchet::from_counts(&counts);
        assert_eq!(r.files.len(), 1);
        assert!(r.files.contains_key("src/b.rs"));
    }

    #[test]
    fn bad_version_or_rule_is_rejected() {
        assert!(Ratchet::parse("{\"version\": 99, \"rule\": \"unwrap-ratchet\", \"files\": {}}")
            .is_err());
        assert!(Ratchet::parse("{\"version\": 1, \"rule\": \"other\", \"files\": {}}").is_err());
        assert!(Ratchet::parse("not json").is_err());
    }
}
